"""Declarative experiment specification: YAML/dict -> :class:`ExperimentSpec`.

One document describes a whole exploration — the paper's "unified"
interface — instead of hand-wiring six subsystems per script::

    name: quickstart
    search_space:            # inline DSL mapping, or {file: path.yaml}
      input: [3, 256]
      output: 4
      sequence: [...]
    sampler: {name: tpe, seed: 0}
    executor: {backend: process, n_workers: 2}
    schedule: {mode: auto, tell_order: trial}    # or sliding_window / batch
    criteria:
      - {estimator: flops, kind: objective, weight: 1.0}
      - {estimator: n_params, kind: soft_constraint, limit: 1e6, weight: 0.1}
      - estimator: latency_s
        kind: objective
        params: {batch: 8, metric: modelled}   # estimator constructor kwargs
    target: host_cpu
    cache: {dir: results/cache}  # or a bare path; omit for memory-only
    persistence: results/quickstart.jsonl      # resumable study storage
    budget: {n_trials: 25, timeout_s: null}
    pruner: {name: median}                     # optional
    scalarize: true          # false -> multi-objective (Pareto) search
    report_dir: results

Component names resolve through :mod:`repro.explorer.registry`, so a
plugin registered under a new key is immediately addressable from YAML.
Validation is eager and errors name the offending key plus the accepted
alternatives — a typo fails at parse time, not trial 37.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
from typing import Any, Dict, List, Mapping, Optional

import yaml

from repro.core.space import SpaceError, parse_search_space
from repro.explorer.registry import (
    ESTIMATORS,
    EXECUTORS,
    PRUNERS,
    SAMPLERS,
    TARGETS,
    ExplorerError,
)


class ExperimentError(ExplorerError):
    """A spec failed validation (bad key, bad value, unknown component)."""


CRITERIA_KINDS = ("objective", "soft_constraint", "hard_constraint")
DIRECTIONS = ("minimize", "maximize")


def _require_mapping(raw: Any, where: str) -> Dict[str, Any]:
    if not isinstance(raw, Mapping):
        raise ExperimentError(f"{where} must be a mapping, got {type(raw).__name__}")
    return dict(raw)


def _check_keys(raw: Mapping[str, Any], allowed: Mapping[str, Any] | set, where: str) -> None:
    unknown = sorted(set(raw) - set(allowed))
    if unknown:
        raise ExperimentError(
            f"unknown key(s) {unknown} in {where}; allowed keys: {sorted(allowed)}"
        )


def _check_component_kwargs(factory: Any, options: Dict[str, Any], where: str) -> None:
    """Bind ``options`` against the component constructor so a bad kwarg
    fails at spec-parse time with the constructor's own message."""
    try:
        inspect.signature(factory).bind(**options)
    except TypeError as e:
        raise ExperimentError(f"{where}: {e}") from None


@dataclasses.dataclass
class SamplerSpec:
    name: str = "random"
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # FIELD_DOCS on every spec class is read by repro.explorer.docgen to
    # generate docs/reference/experiment_spec.md — the table lives next
    # to the validator so the two cannot drift
    FIELD_DOCS = {
        "name": "registered sampler key (see `components.md`); a bare "
                "string is shorthand for `{name: ...}`",
        "options": "every other key is passed to the sampler constructor "
                   "and validated against its signature at parse time "
                   "(e.g. `seed`, `population`)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "sampler") -> "SamplerSpec":
        if raw is None:
            return cls()
        if isinstance(raw, str):
            raw = {"name": raw}
        raw = _require_mapping(raw, where)
        options = dict(raw)
        name = options.pop("name", None)
        if name is None:
            raise ExperimentError(
                f"{where}: missing 'name'; registered samplers: {SAMPLERS.names()}"
            )
        factory = SAMPLERS.get(name)  # raises UnknownComponentError with alternatives
        _check_component_kwargs(factory, options, where)
        return cls(name=str(name), options=options)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, **self.options}

    def build(self):
        return SAMPLERS.get(self.name)(**self.options)


@dataclasses.dataclass
class PrunerSpec:
    name: str
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    FIELD_DOCS = {
        "name": "registered pruner key; omit the whole `pruner` section "
                "to disable pruning",
        "options": "remaining keys go to the pruner constructor "
                   "(e.g. `n_startup_trials`, `reduction_factor`)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "pruner") -> Optional["PrunerSpec"]:
        if raw is None:
            return None
        if isinstance(raw, str):
            raw = {"name": raw}
        raw = _require_mapping(raw, where)
        options = dict(raw)
        name = options.pop("name", None)
        if name is None:
            raise ExperimentError(
                f"{where}: missing 'name'; registered pruners: {PRUNERS.names()}"
            )
        factory = PRUNERS.get(name)
        _check_component_kwargs(factory, options, where)
        return cls(name=str(name), options=options)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, **self.options}

    def build(self):
        return PRUNERS.get(self.name)(**self.options)


@dataclasses.dataclass
class ExecutorSpec:
    backend: str = "serial"
    n_workers: int = 1
    workers: Optional[List[str]] = None
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    KEYS = ("backend", "n_workers", "workers", "options")
    FIELD_DOCS = {
        "backend": "registered executor key (`serial`/`thread`/`process`/"
                   "`remote` built in); a bare string is shorthand for "
                   "`{backend: ...}`",
        "n_workers": "worker slots (>= 1); also the default sliding-window "
                     "size.  Defaults to the length of `workers` when a "
                     "worker pool is given, else 1",
        "workers": "worker-daemon addresses (`[\"host:port\", ...]`) for "
                   "the `remote` backend; forwarded to the executor "
                   "constructor, so backends whose constructor takes no "
                   "`workers` reject it at parse time",
        "options": "mapping of extra executor-constructor kwargs, validated "
                   "against the signature at parse time (e.g. `retries`, "
                   "`heartbeat_timeout_s`, `task_timeout_s`, `fallback` "
                   "for `remote`; `mp_context` for `process`)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "executor") -> "ExecutorSpec":
        if raw is None:
            return cls()
        if isinstance(raw, str):
            raw = {"backend": raw}
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        backend = str(raw.get("backend", "serial"))
        factory = EXECUTORS.get(backend)
        workers = raw.get("workers")
        if workers is not None:
            if (not isinstance(workers, (list, tuple)) or not workers
                    or not all(isinstance(w, str) for w in workers)):
                raise ExperimentError(
                    f"{where}: workers must be a non-empty list of "
                    f"'host:port' strings")
            for w in workers:
                host, _, port = w.rpartition(":")
                if not host or not port.isdigit():
                    raise ExperimentError(
                        f"{where}: worker address {w!r} is not host:port")
            workers = [str(w) for w in workers]
        options = raw.get("options")
        options = dict(_require_mapping(options, f"{where}.options")) if options else {}
        # bind workers + options against the constructor: `workers` on a
        # backend that takes none (serial/thread/process) fails here with
        # the constructor's own message
        probe = dict(options)
        if workers is not None:
            probe["workers"] = workers
        _check_component_kwargs(factory, probe, where)
        n_workers = raw.get("n_workers")
        if n_workers is None:
            n_workers = len(workers) if workers else 1
        n_workers = int(n_workers)
        if n_workers < 1:
            raise ExperimentError(f"{where}: n_workers must be >= 1, got {n_workers}")
        return cls(backend=backend, n_workers=n_workers, workers=workers,
                   options=options)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"backend": self.backend, "n_workers": self.n_workers}
        if self.workers is not None:
            out["workers"] = list(self.workers)
        if self.options:
            out["options"] = dict(self.options)
        return out

    def build(self):
        kwargs = dict(self.options)
        if self.workers is not None:
            kwargs["workers"] = list(self.workers)
        return EXECUTORS.get(self.backend)(**kwargs)


@dataclasses.dataclass
class ScheduleSpec:
    """How ``ParallelStudy`` schedules trials: ``mode`` is ``auto``
    (sliding window for order-independent samplers, batch otherwise),
    ``batch``, or ``sliding_window``; ``tell_order`` is ``trial``
    (reorder buffer, deterministic storage order) or ``completion``
    (fastest, run-dependent storage order); ``window`` bounds in-flight
    submissions (default: n_workers)."""

    mode: str = "auto"
    tell_order: str = "trial"
    window: Optional[int] = None

    KEYS = ("mode", "tell_order", "window")
    MODES = ("auto", "batch", "sliding_window")
    TELL_ORDERS = ("trial", "completion")
    FIELD_DOCS = {
        "mode": "one of `auto` | `batch` | `sliding_window`; `auto` picks "
                "sliding for order-independent samplers (random/grid), "
                "batch for history-consulting ones; a bare string is "
                "shorthand for `{mode: ...}`",
        "tell_order": "`trial` (reorder buffer, deterministic storage "
                      "order) or `completion` (fastest; tells land as "
                      "evaluations finish)",
        "window": "max in-flight submissions under the sliding window "
                  "(integer >= 1; default: `n_workers`)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "schedule") -> "ScheduleSpec":
        if raw is None:
            return cls()
        if isinstance(raw, str):
            raw = {"mode": raw}
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        mode = str(raw.get("mode", "auto"))
        if mode not in cls.MODES:
            raise ExperimentError(
                f"{where}: unknown mode {mode!r}; expected one of {cls.MODES}")
        tell_order = str(raw.get("tell_order", "trial"))
        if tell_order not in cls.TELL_ORDERS:
            raise ExperimentError(
                f"{where}: unknown tell_order {tell_order!r}; expected one of "
                f"{cls.TELL_ORDERS}")
        window = raw.get("window")
        if window is not None:
            try:
                window = int(window)
            except (TypeError, ValueError):
                raise ExperimentError(
                    f"{where}: window must be an integer, got {window!r}") from None
            if window < 1:
                raise ExperimentError(f"{where}: window must be >= 1, got {window}")
        return cls(mode=mode, tell_order=tell_order, window=window)

    def to_dict(self) -> Dict[str, Any]:
        return {"mode": self.mode, "tell_order": self.tell_order,
                "window": self.window}


@dataclasses.dataclass
class CriterionSpec:
    estimator: str
    kind: str = "objective"
    direction: str = "minimize"
    weight: float = 1.0
    limit: Optional[float] = None
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    KEYS = ("estimator", "kind", "direction", "weight", "limit", "params")
    FIELD_DOCS = {
        "estimator": "registered estimator key; a bare string is "
                     "shorthand for `{estimator: ...}`; each estimator "
                     "may appear at most once",
        "kind": "one of `objective` | `soft_constraint` | "
                "`hard_constraint`; at least one criterion must be an "
                "objective",
        "direction": "`minimize` (default) or `maximize`",
        "weight": "scalarization weight (float, default 1.0)",
        "limit": "constraint threshold; required for both constraint "
                 "kinds, ignored for objectives",
        "params": "estimator constructor kwargs, validated against its "
                  "signature at parse time (`target`, `cache`, `tuner`, "
                  "and `serving` are injected by the Explorer)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str) -> "CriterionSpec":
        if isinstance(raw, str):
            raw = {"estimator": raw}
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        name = raw.get("estimator")
        if name is None:
            raise ExperimentError(
                f"{where}: missing 'estimator'; registered estimators: "
                f"{ESTIMATORS.names()}"
            )
        factory = ESTIMATORS.get(name)
        kind = str(raw.get("kind", "objective"))
        if kind not in CRITERIA_KINDS:
            raise ExperimentError(
                f"{where}: unknown kind {kind!r}; expected one of {CRITERIA_KINDS}"
            )
        direction = str(raw.get("direction", "minimize"))
        if direction not in DIRECTIONS:
            raise ExperimentError(
                f"{where}: unknown direction {direction!r}; expected one of {DIRECTIONS}"
            )
        limit = raw.get("limit")
        if kind != "objective" and limit is None:
            raise ExperimentError(f"{where}: kind {kind!r} requires a 'limit'")
        params = _require_mapping(raw.get("params") or {}, f"{where}.params")
        # target/cache/tuner/serving are injected by the Explorer;
        # everything else must bind against the estimator constructor
        probe = dict(params)
        sig_params = inspect.signature(factory).parameters
        for injected in ("target", "cache", "tuner", "serving"):
            if injected in sig_params:
                probe.setdefault(injected, None)
        _check_component_kwargs(factory, probe, where)
        return cls(
            estimator=str(name), kind=kind, direction=direction,
            weight=float(raw.get("weight", 1.0)),
            limit=None if limit is None else float(limit),
            params=params,
        )

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "estimator": self.estimator, "kind": self.kind,
            "direction": self.direction, "weight": self.weight,
        }
        if self.limit is not None:
            d["limit"] = self.limit
        if self.params:
            d["params"] = dict(self.params)
        return d

    def build_estimator(self, target: Any = None, cache: Any = None,
                        tuner: Any = None, serving: Any = None):
        """Instantiate the estimator, injecting the experiment's hardware
        target, shared cache, kernel-schedule tuner, and serving spec
        wherever the constructor accepts them."""
        factory = ESTIMATORS.get(self.estimator)
        kwargs = dict(self.params)
        sig_params = inspect.signature(factory).parameters
        for name, value in (("target", target), ("cache", cache),
                            ("tuner", tuner), ("serving", serving)):
            if name in sig_params and name not in kwargs and value is not None:
                kwargs[name] = value
        return factory(**kwargs)


@dataclasses.dataclass
class CacheSpec:
    dir: Optional[str] = None  # disk store directory; None = memory-only

    FIELD_DOCS = {
        "dir": "disk store directory for the persistent cache tier; a "
               "bare path or `true` (default `results/cache`) are "
               "shorthand; omit the section for a memory-only cache",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "cache") -> "CacheSpec":
        if raw is None or raw is False:
            return cls()
        if raw is True:
            from repro.evaluation.disk_cache import DEFAULT_DIR

            return cls(dir=DEFAULT_DIR)
        if isinstance(raw, (str, os.PathLike)):
            return cls(dir=str(raw))
        raw = _require_mapping(raw, where)
        _check_keys(raw, {"dir"}, where)
        d = raw.get("dir")
        return cls(dir=None if d is None else str(d))

    def to_dict(self) -> Dict[str, Any]:
        return {"dir": self.dir}


@dataclasses.dataclass
class BudgetSpec:
    n_trials: int = 25
    timeout_s: Optional[float] = None

    KEYS = ("n_trials", "timeout_s")
    FIELD_DOCS = {
        "n_trials": "total trial budget (>= 1; resumed trials from "
                    "`persistence` count against it); a bare integer is "
                    "shorthand for `{n_trials: ...}`",
        "timeout_s": "wall-clock deadline, enforced per-submission under "
                     "the sliding window / per-batch under the batch "
                     "scheduler; `null` = no deadline",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "budget") -> "BudgetSpec":
        if raw is None:
            return cls()
        if isinstance(raw, int):
            raw = {"n_trials": raw}
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        n_trials = int(raw.get("n_trials", 25))
        if n_trials < 1:
            raise ExperimentError(f"{where}: n_trials must be >= 1, got {n_trials}")
        timeout = raw.get("timeout_s")
        return cls(n_trials=n_trials,
                   timeout_s=None if timeout is None else float(timeout))

    def to_dict(self) -> Dict[str, Any]:
        return {"n_trials": self.n_trials, "timeout_s": self.timeout_s}


@dataclasses.dataclass
class KeepSpec:
    """Survivor rule for one screening stage — exactly one key."""

    top_k: Optional[int] = None
    top_frac: Optional[float] = None
    threshold: Optional[float] = None

    KEYS = ("top_k", "top_frac", "threshold")
    FIELD_DOCS = {
        "top_k": "keep the k best-ranked candidates of the cohort "
                 "(integer >= 1; lower stage score ranks better, ties "
                 "keep ask order)",
        "top_frac": "keep the best `ceil(frac * cohort)` candidates "
                    "(float in (0, 1]; always at least one)",
        "threshold": "keep candidates whose scalarized stage score is "
                     "<= this value (per-candidate; no cohort ranking)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str) -> "KeepSpec":
        if raw is None:
            raise ExperimentError(
                f"{where}: missing 'keep'; every fidelity stage needs a "
                f"survivor rule (one of {cls.KEYS})")
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        set_keys = [k for k in cls.KEYS if raw.get(k) is not None]
        if len(set_keys) != 1:
            raise ExperimentError(
                f"{where}: exactly one of {cls.KEYS} must be set, "
                f"got {set_keys or 'none'}")
        top_k = raw.get("top_k")
        if top_k is not None:
            top_k = int(top_k)
            if top_k < 1:
                raise ExperimentError(f"{where}: top_k must be >= 1, got {top_k}")
        top_frac = raw.get("top_frac")
        if top_frac is not None:
            top_frac = float(top_frac)
            if not 0.0 < top_frac <= 1.0:
                raise ExperimentError(
                    f"{where}: top_frac must be in (0, 1], got {top_frac}")
        threshold = raw.get("threshold")
        return cls(top_k=top_k, top_frac=top_frac,
                   threshold=None if threshold is None else float(threshold))

    def to_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self.KEYS
                if getattr(self, k) is not None}


@dataclasses.dataclass
class StageSpec:
    """One screening stage of the fidelity cascade (the *final* stage is
    the experiment's top-level ``criteria`` and needs no declaration)."""

    name: str = ""
    criteria: List[CriterionSpec] = dataclasses.field(default_factory=list)
    keep: KeepSpec = dataclasses.field(default_factory=KeepSpec)

    KEYS = ("name", "criteria", "keep")
    FIELD_DOCS = {
        "name": "stage label, recorded on screened-out trials as "
                "`user_attrs[\"fidelity_stage\"]`; must be unique and not "
                "`final` (reserved for the top-level criteria)",
        "criteria": "criterion entries exactly like the top-level "
                    "`criteria` list (zero-cost proxies `synflow` / "
                    "`grad_norm` and analytic estimators are the natural "
                    "fit); at least one `kind: objective`",
        "keep": "survivor rule (see table below)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str) -> "StageSpec":
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        name = raw.get("name")
        if not name or not isinstance(name, str):
            raise ExperimentError(f"{where}: missing or empty 'name'")
        if name in ("final", "promoted"):
            raise ExperimentError(
                f"{where}: stage name {name!r} is reserved (the top-level "
                f"criteria form the final stage; 'promoted' marks survivors)")
        raw_criteria = raw.get("criteria")
        if not isinstance(raw_criteria, (list, tuple)) or not raw_criteria:
            raise ExperimentError(
                f"{where}: criteria must be a non-empty list of criterion "
                f"entries")
        criteria = [CriterionSpec.from_raw(c, f"{where}.criteria[{i}]")
                    for i, c in enumerate(raw_criteria)]
        if not any(c.kind == "objective" for c in criteria):
            raise ExperimentError(
                f"{where}: a screening stage needs at least one "
                f"kind='objective' criterion to rank the cohort by")
        return cls(name=name, criteria=criteria,
                   keep=KeepSpec.from_raw(raw.get("keep"), f"{where}.keep"))

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name,
                "criteria": [c.to_dict() for c in self.criteria],
                "keep": self.keep.to_dict()}


@dataclasses.dataclass
class FidelitySpec:
    """The multi-fidelity evaluation cascade: candidates are asked a
    *generation* at a time, screened in-process through the declared
    stages (cheapest first), and only survivors are promoted to the
    executor for the full (compiled) top-level criteria."""

    stages: List[StageSpec] = dataclasses.field(default_factory=list)
    generation: int = 16

    KEYS = ("stages", "generation")
    FIELD_DOCS = {
        "stages": "**required** — non-empty list of screening stages, "
                  "cheapest first (see table below); the experiment's "
                  "top-level `criteria` are the implicit final stage",
        "generation": "cohort size: how many trials are asked and "
                      "screened together before survivors are promoted "
                      "(integer >= 1, default 16)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "fidelity") -> Optional["FidelitySpec"]:
        if raw is None:
            return None
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        raw_stages = raw.get("stages")
        if not isinstance(raw_stages, (list, tuple)) or not raw_stages:
            raise ExperimentError(
                f"{where}: stages must be a non-empty list of "
                f"{{name, criteria, keep}} entries")
        stages = [StageSpec.from_raw(s, f"{where}.stages[{i}]")
                  for i, s in enumerate(raw_stages)]
        names = [s.name for s in stages]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ExperimentError(
                f"{where}: duplicate stage name(s) {dupes}")
        generation = int(raw.get("generation", 16))
        if generation < 1:
            raise ExperimentError(
                f"{where}: generation must be >= 1, got {generation}")
        return cls(stages=stages, generation=generation)

    def to_dict(self) -> Dict[str, Any]:
        return {"stages": [s.to_dict() for s in self.stages],
                "generation": self.generation}


@dataclasses.dataclass
class KernelTuningSpec:
    """Kernel-schedule tuning: make the Pallas block/chunk parameters a
    per-target search dimension.  ``mode: cached`` attaches a
    :class:`~repro.hwgen.autotune.ScheduleTuner` that sweeps a small
    candidate grid per (kernel, shape-bucket, target) and memoizes the
    winner in the evaluation cache; ``mode: search`` instead exposes the
    schedule fields as extra trial parameters so the sampler co-optimizes
    architecture × schedule."""

    mode: str = "off"
    budget: Optional[int] = None
    kernels: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)

    KEYS = ("mode", "budget", "kernels")
    MODES = ("off", "cached", "search")
    FIELD_DOCS = {
        "mode": "`off` (default) | `cached` — autotune each kernel per "
                "(shape-bucket, target) and cache the winner, zero "
                "re-tuning on warm restart | `search` — schedule fields "
                "become trial parameters the sampler optimizes; a bare "
                "string is shorthand for `{mode: ...}`",
        "budget": "max schedule candidates timed per kernel/shape-bucket "
                  "sweep (integer >= 1); wins over `REPRO_TUNE_BUDGET`; "
                  "grids are default-first, so 1 degenerates to the "
                  "named `default` schedule",
        "kernels": "per-kernel schedule overrides, e.g. "
                   "`{ssm_scan: {chunk: 64}}` — pinned kernels are never "
                   "tuned (`cached`) or searched (`search`); fields are "
                   "validated against the kernel's legal ranges at parse "
                   "time",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "kernel_tuning"
                 ) -> Optional["KernelTuningSpec"]:
        from repro.kernels.schedule import (KERNEL_FIELDS, ScheduleError,
                                            as_schedule)

        if raw is None:
            return None
        if isinstance(raw, str):
            raw = {"mode": raw}
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        mode = str(raw.get("mode", "off"))
        if mode not in cls.MODES:
            raise ExperimentError(
                f"{where}: unknown mode {mode!r}; expected one of {cls.MODES}")
        budget = raw.get("budget")
        if budget is not None:
            try:
                budget = int(budget)
            except (TypeError, ValueError):
                raise ExperimentError(
                    f"{where}: budget must be an integer, got {budget!r}"
                ) from None
            if budget < 1:
                raise ExperimentError(
                    f"{where}: budget must be >= 1, got {budget}")
        kernels: Dict[str, Dict[str, Any]] = {}
        for kernel, fields in _require_mapping(raw.get("kernels") or {},
                                               f"{where}.kernels").items():
            if kernel not in KERNEL_FIELDS:
                raise ExperimentError(
                    f"{where}.kernels: unknown kernel {kernel!r}; "
                    f"schedulable kernels: {sorted(KERNEL_FIELDS)}")
            fields = _require_mapping(fields, f"{where}.kernels.{kernel}")
            try:
                as_schedule(kernel, fields)
            except ScheduleError as e:
                raise ExperimentError(f"{where}.kernels.{kernel}: {e}") from None
            kernels[kernel] = dict(fields)
        return cls(mode=mode, budget=budget, kernels=kernels)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"mode": self.mode}
        if self.budget is not None:
            d["budget"] = self.budget
        if self.kernels:
            d["kernels"] = {k: dict(v) for k, v in self.kernels.items()}
        return d


@dataclasses.dataclass
class FaultsSpec:
    """Deterministic fault injection (chaos testing a run on purpose).

    The section validates into a :class:`repro.faults.FaultPlan`;
    :meth:`Explorer.run` installs it for the run's duration and exports
    it through ``REPRO_FAULTS`` so spawned process workers inherit the
    same seeded schedule."""

    seed: int = 0
    rules: List[str] = dataclasses.field(default_factory=list)

    KEYS = ("seed", "rules")
    FIELD_DOCS = {
        "seed": "seed for the plan's per-rule RNG streams — the same "
                "seed reproduces the same fault schedule on every run "
                "and every backend (default 0)",
        "rules": "non-empty list of `site:action[@k=v,...]` rule strings "
                 "or `{site, action, p, times, after, delay_s, key}` "
                 "mappings (see `docs/architecture.md` for the site and "
                 "action tables); a bare string section is shorthand for "
                 "the whole `REPRO_FAULTS` spec string",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "faults"
                 ) -> Optional["FaultsSpec"]:
        from repro.faults import FaultPlan

        if raw is None:
            return None
        try:
            if isinstance(raw, str):
                plan = FaultPlan.from_string(raw)
            else:
                plan = FaultPlan.from_spec(_require_mapping(raw, where))
        except ValueError as e:
            raise ExperimentError(f"{where}: {e}") from None
        if not plan.rules:
            raise ExperimentError(
                f"{where}: needs at least one rule (omit the section to "
                f"run without injection)")
        return cls(seed=plan.seed, rules=[r.to_string() for r in plan.rules])

    def plan(self):
        """The validated, installable :class:`repro.faults.FaultPlan`."""
        from repro.faults import FaultPlan

        return FaultPlan.from_spec({"seed": self.seed, "rules": list(self.rules)})

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"rules": list(self.rules)}
        if self.seed:
            d["seed"] = self.seed
        return d


@dataclasses.dataclass
class ServingSpec:
    """Traffic-shaped serving criteria: how the engine batches and what
    load it sees.  Injected into estimators that accept a ``serving``
    kwarg (the :mod:`repro.evaluation.serving` family), so sweeps rank
    candidates by p99 latency / throughput *under the declared traffic
    mix* rather than single-request kernel time; the same section drives
    ``python -m repro.launch.serve`` so the measured engine and the
    estimators model the same configuration."""

    traffic: "Any" = None  # TrafficSpec; default built in __post_init__
    max_batch: int = 8
    queue_limit: int = 16
    dtype_bytes: int = 2

    KEYS = ("traffic", "max_batch", "queue_limit", "dtype_bytes")
    FIELD_DOCS = {
        "traffic": "declared traffic mix (see table below): seeded "
                   "arrival process + prompt/generation length mixes; "
                   "replays bit-identically at a fixed seed",
        "max_batch": "continuous-batching concurrency limit — the engine "
                     "decodes at most this many requests per step "
                     "(integer >= 1, default 8)",
        "queue_limit": "bounded admission queue depth; arrivals beyond it "
                       "are shed gracefully (integer >= 1, default 16)",
        "dtype_bytes": "bytes per decode-cache element (2 = bf16 default, "
                       "4 = f32); scales `kv_cache_peak_bytes` and the "
                       "decode-state bandwidth term",
    }

    def __post_init__(self):
        from repro.launch.traffic import TrafficSpec

        if self.traffic is None:
            self.traffic = TrafficSpec()

    @classmethod
    def from_raw(cls, raw: Any, where: str = "serving"
                 ) -> Optional["ServingSpec"]:
        from repro.launch.traffic import TrafficError, TrafficSpec

        if raw is None:
            return None
        raw = _require_mapping(raw, where)
        _check_keys(raw, set(cls.KEYS), where)
        try:
            traffic = TrafficSpec.from_raw(raw.get("traffic"),
                                           f"{where}.traffic")
        except TrafficError as e:
            raise ExperimentError(str(e)) from None
        max_batch = int(raw.get("max_batch", 8))
        if max_batch < 1:
            raise ExperimentError(
                f"{where}: max_batch must be >= 1, got {max_batch}")
        queue_limit = int(raw.get("queue_limit", 16))
        if queue_limit < 1:
            raise ExperimentError(
                f"{where}: queue_limit must be >= 1, got {queue_limit}")
        dtype_bytes = int(raw.get("dtype_bytes", 2))
        if dtype_bytes not in (1, 2, 4, 8):
            raise ExperimentError(
                f"{where}: dtype_bytes must be one of (1, 2, 4, 8), "
                f"got {dtype_bytes}")
        return cls(traffic=traffic, max_batch=max_batch,
                   queue_limit=queue_limit, dtype_bytes=dtype_bytes)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "traffic": self.traffic.to_dict(),
            "max_batch": self.max_batch,
            "queue_limit": self.queue_limit,
            "dtype_bytes": self.dtype_bytes,
        }


TOP_LEVEL_KEYS = (
    "name", "search_space", "sampler", "executor", "schedule", "criteria",
    "fidelity", "kernel_tuning", "target", "cache", "persistence", "budget",
    "pruner", "scalarize", "report_dir", "faults", "serving",
)

# descriptions for the top-level experiment document, rendered into
# docs/reference/experiment_spec.md by repro.explorer.docgen; every key
# in TOP_LEVEL_KEYS must appear here (asserted by the docs generator)
TOP_LEVEL_DOCS = {
    "name": "experiment name; names the report artifact "
            "`<report_dir>/<name>.report.json` (default: `experiment`)",
    "search_space": "**required** — inline search-space DSL mapping, or "
                    "`{file: path.yaml}` (relative paths resolve against "
                    "the experiment file; the loaded space is inlined so "
                    "the spec stays self-contained)",
    "sampler": "which sampler proposes trials (see table below)",
    "executor": "where objective evaluations run (see table below)",
    "schedule": "how `ParallelStudy` schedules trials (see table below)",
    "criteria": "**required** — non-empty list of criterion entries "
                "(see table below); at least one `kind: objective`",
    "fidelity": "optional multi-fidelity evaluation cascade (see table "
                "below): candidates are screened a generation at a time "
                "through cheap stages before the top-level criteria — the "
                "implicit final stage — run on the survivors",
    "kernel_tuning": "optional kernel-schedule tuning (see table below): "
                     "Pallas block/chunk parameters become a per-target "
                     "tuning dimension, autotuned+cached (`cached`) or "
                     "co-searched with the architecture (`search`)",
    "target": "registered hardware target key (default `host_cpu`); "
              "injected into estimators that accept a `target` kwarg",
    "cache": "evaluation-cache configuration (see table below)",
    "persistence": "study storage JSONL path; re-running resumes stored "
                   "trials against the budget (default: in-memory only)",
    "budget": "how much to search (see table below)",
    "pruner": "optional early-stopping pruner (see table below)",
    "scalarize": "`true` (default): weighted-sum single-objective search; "
                 "`false`: multi-objective (Pareto) — rejects "
                 "soft constraints, which only exist in scalarized mode",
    "report_dir": "directory for the report artifact (default `results`)",
    "faults": "optional deterministic fault injection (see table below): "
              "a seeded chaos schedule installed for the run and "
              "inherited by spawned process workers via `REPRO_FAULTS`",
    "serving": "optional serving configuration (see table below): "
               "continuous-batching limits plus a seeded traffic mix; "
               "injected into the traffic-shaped estimators "
               "(`p99_latency_s`, `throughput_tok_s`, ...) and recorded "
               "in the report for `repro.launch.serve --from-report`",
}


def _resolve_search_space(raw: Any, base_dir: Optional[str]) -> Dict[str, Any]:
    """Inline mapping, inline YAML text, or ``{file: path}`` reference
    (relative paths resolve against the experiment file's directory).
    Always returns the loaded mapping so the spec is self-contained and
    picklable regardless of where it came from."""
    if raw is None:
        raise ExperimentError(
            f"missing 'search_space'; provide an inline space mapping or "
            f"{{file: path.yaml}}"
        )
    if isinstance(raw, Mapping) and set(raw) == {"file"}:
        path = str(raw["file"])
        if base_dir and not os.path.isabs(path):
            path = os.path.join(base_dir, path)
        if not os.path.exists(path):
            raise ExperimentError(f"search_space file not found: {path!r}")
        with open(path) as f:
            raw = yaml.safe_load(f.read())
    elif isinstance(raw, str):
        raw = yaml.safe_load(raw)
    if not isinstance(raw, Mapping):
        raise ExperimentError(
            f"search_space must be a mapping (inline DSL or {{file: path}}), "
            f"got {type(raw).__name__}"
        )
    return dict(raw)


@dataclasses.dataclass
class ExperimentSpec:
    """A fully validated, JSON-serializable experiment description."""

    name: str
    search_space: Dict[str, Any]
    criteria: List[CriterionSpec]
    sampler: SamplerSpec = dataclasses.field(default_factory=SamplerSpec)
    executor: ExecutorSpec = dataclasses.field(default_factory=ExecutorSpec)
    schedule: ScheduleSpec = dataclasses.field(default_factory=ScheduleSpec)
    target: str = "host_cpu"
    cache: CacheSpec = dataclasses.field(default_factory=CacheSpec)
    persistence: Optional[str] = None
    budget: BudgetSpec = dataclasses.field(default_factory=BudgetSpec)
    pruner: Optional[PrunerSpec] = None
    fidelity: Optional[FidelitySpec] = None
    kernel_tuning: Optional[KernelTuningSpec] = None
    faults: Optional[FaultsSpec] = None
    serving: Optional[ServingSpec] = None
    scalarize: bool = True
    report_dir: str = "results"

    # -- construction ----------------------------------------------------------

    @classmethod
    def from_dict(cls, raw: Mapping[str, Any],
                  base_dir: Optional[str] = None) -> "ExperimentSpec":
        raw = _require_mapping(raw, "experiment")
        _check_keys(raw, set(TOP_LEVEL_KEYS), "experiment")

        space_dict = _resolve_search_space(raw.get("search_space"), base_dir)
        try:
            parse_search_space(dict(space_dict))
        except SpaceError as e:
            raise ExperimentError(f"search_space: {e}") from e

        raw_criteria = raw.get("criteria")
        if not isinstance(raw_criteria, (list, tuple)) or not raw_criteria:
            raise ExperimentError(
                "criteria must be a non-empty list of "
                "{estimator, kind, direction, weight, limit, params} entries"
            )
        criteria = [CriterionSpec.from_raw(c, f"criteria[{i}]")
                    for i, c in enumerate(raw_criteria)]
        objectives = [c for c in criteria if c.kind == "objective"]
        if not objectives:
            raise ExperimentError(
                "criteria must include at least one kind='objective' entry "
                "(constraints alone give every candidate the same score)"
            )
        names = [c.estimator for c in criteria]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ExperimentError(
                f"criteria reference estimator(s) {dupes} more than once; "
                f"scores aggregate by estimator name, so duplicates collide"
            )

        fidelity = FidelitySpec.from_raw(raw.get("fidelity"))
        if fidelity is not None:
            # estimator names must be unique across the WHOLE cascade —
            # every stage records values on the trial by estimator name
            cascade_names = list(names)
            for s in fidelity.stages:
                cascade_names.extend(c.estimator for c in s.criteria)
            dupes = sorted({n for n in cascade_names
                            if cascade_names.count(n) > 1})
            if dupes:
                raise ExperimentError(
                    f"fidelity stages and criteria reference estimator(s) "
                    f"{dupes} more than once across the cascade; trial "
                    f"values record by estimator name, so duplicates collide"
                )

        target = str(raw.get("target", "host_cpu"))
        TARGETS.get(target)

        scalarize = bool(raw.get("scalarize", True))
        if not scalarize:
            soft = [c.estimator for c in criteria if c.kind == "soft_constraint"]
            if soft:
                raise ExperimentError(
                    f"scalarize: false ignores soft constraints (multi-objective "
                    f"evaluation only runs hard constraints and objectives), but "
                    f"criteria declare soft_constraint(s) {soft}; use "
                    f"kind: hard_constraint, promote them to objectives, or keep "
                    f"scalarize: true"
                )
        persistence = raw.get("persistence")
        return cls(
            name=str(raw.get("name", "experiment")),
            search_space=space_dict,
            criteria=criteria,
            sampler=SamplerSpec.from_raw(raw.get("sampler")),
            executor=ExecutorSpec.from_raw(raw.get("executor")),
            schedule=ScheduleSpec.from_raw(raw.get("schedule")),
            target=target,
            cache=CacheSpec.from_raw(raw.get("cache")),
            persistence=None if persistence is None else str(persistence),
            budget=BudgetSpec.from_raw(raw.get("budget")),
            pruner=PrunerSpec.from_raw(raw.get("pruner")),
            fidelity=fidelity,
            kernel_tuning=KernelTuningSpec.from_raw(raw.get("kernel_tuning")),
            faults=FaultsSpec.from_raw(raw.get("faults")),
            serving=ServingSpec.from_raw(raw.get("serving")),
            scalarize=scalarize,
            report_dir=str(raw.get("report_dir", "results")),
        )

    @classmethod
    def from_yaml(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            raw = yaml.safe_load(f.read())
        return cls.from_dict(raw, base_dir=os.path.dirname(os.path.abspath(path)))

    @classmethod
    def from_yaml_text(cls, text: str, base_dir: Optional[str] = None) -> "ExperimentSpec":
        return cls.from_dict(yaml.safe_load(text), base_dir=base_dir)

    # -- serialization ---------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able round-trip form: ``from_dict(spec.to_dict())`` is
        equivalent to ``spec`` (search-space file refs come back inlined)."""
        d: Dict[str, Any] = {
            "name": self.name,
            "search_space": dict(self.search_space),
            "sampler": self.sampler.to_dict(),
            "executor": self.executor.to_dict(),
            "schedule": self.schedule.to_dict(),
            "criteria": [c.to_dict() for c in self.criteria],
            "target": self.target,
            "cache": self.cache.to_dict(),
            "budget": self.budget.to_dict(),
            "scalarize": self.scalarize,
            "report_dir": self.report_dir,
        }
        if self.persistence is not None:
            d["persistence"] = self.persistence
        if self.pruner is not None:
            d["pruner"] = self.pruner.to_dict()
        if self.fidelity is not None:
            d["fidelity"] = self.fidelity.to_dict()
        if self.kernel_tuning is not None:
            d["kernel_tuning"] = self.kernel_tuning.to_dict()
        if self.faults is not None:
            d["faults"] = self.faults.to_dict()
        if self.serving is not None:
            d["serving"] = self.serving.to_dict()
        return d

    # -- derived views ---------------------------------------------------------

    @property
    def objective_criteria(self) -> List[CriterionSpec]:
        return [c for c in self.criteria if c.kind == "objective"]

    @property
    def directions(self) -> tuple:
        """Study directions: the scalarized score always minimizes (the
        aggregator folds maximize objectives in by sign); multi-objective
        mode optimizes each objective in its declared direction."""
        if self.scalarize:
            return ("minimize",)
        return tuple(c.direction for c in self.objective_criteria)
