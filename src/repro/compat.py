"""Version-compatibility shims for the installed JAX.

The codebase targets the newest JAX mesh API (explicit ``axis_types``),
but the pinned toolchain in some environments predates
``jax.sharding.AxisType`` (added after 0.4.37, where the attribute is a
deprecation stub that raises).  Everything that builds a mesh goes
through :func:`mesh_axis_kwargs` so the rest of the code never has to
know which JAX it is running on.
"""
from __future__ import annotations

from typing import Any, Dict

import jax


def has_axis_types() -> bool:
    """True when ``jax.make_mesh`` accepts ``axis_types``."""
    try:
        return getattr(jax.sharding, "AxisType", None) is not None
    except Exception:
        return False


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()`` across JAX versions.

    Newer JAX returns a flat dict; 0.4.x returns a one-element list of
    per-program dicts.  Always returns a (possibly empty) dict.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def mesh_axis_kwargs(ndim: int) -> Dict[str, Any]:
    """Extra ``jax.make_mesh`` kwargs for an ``ndim``-axis mesh.

    Returns ``{"axis_types": (Auto,) * ndim}`` on JAX versions that
    support explicit axis types, and ``{}`` otherwise (older JAX treats
    every axis as Auto implicitly, so the semantics are unchanged).
    """
    if has_axis_types():
        return {"axis_types": (jax.sharding.AxisType.Auto,) * ndim}
    return {}
