"""Fault-tolerant checkpointing.

Design (pod-scale requirements):
  * atomic: write to ``step_N.tmp/`` then rename — a preempted writer
    never corrupts the latest checkpoint;
  * sharded-friendly: each leaf is fetched shard-by-shard
    (``jax.device_get`` per addressable shard on real pods; whole-array
    on the host backend) and stored as .npy inside the step directory,
    with the tree structure in a msgpack/JSON manifest;
  * async: ``save_async`` snapshots to host memory synchronously (one
    device->host copy) and writes to disk on a worker thread so training
    continues during I/O;
  * elastic restore: ``restore`` returns host arrays that jax re-shards
    to WHATEVER mesh/sharding the caller passes (device counts may have
    changed after a failure — checkpoint resharding);
  * retention: keep the newest K checkpoints.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ------------------------------------------------------------

    def _write(self, step: int, host_leaves: List[Tuple[str, np.ndarray]], treedef_json: str):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (key, arr) in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({"key": key, "file": fname,
                                       "shape": list(arr.shape), "dtype": str(arr.dtype)})
        manifest["treedef"] = treedef_json
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        self._retain()

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    def _to_host(self, tree) -> Tuple[List[Tuple[str, np.ndarray]], str]:
        leaves = _flatten_with_paths(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in leaves]
        structure = jax.tree_util.tree_structure(tree)
        return host, str(structure)

    def save(self, step: int, tree) -> None:
        host, treedef = self._to_host(tree)
        self._write(step, host, treedef)

    def save_async(self, step: int, tree) -> None:
        if self._error:
            raise self._error
        host, treedef = self._to_host(tree)  # sync device->host snapshot
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._q.put((step, host, treedef))

    def _drain(self):
        while True:
            try:
                item = self._q.get(timeout=5.0)
            except queue.Empty:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next save_async
                self._error = e
            finally:
                self._q.task_done()

    def wait(self):
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
        if self._error:
            raise self._error

    # -- read ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, like=None, shardings=None):
        """Load a checkpoint.  ``like`` (a pytree) provides the structure;
        ``shardings`` (same-structure tree of NamedShardings) reshards onto
        the current mesh — device topology may differ from save time."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        for entry in manifest["leaves"]:
            arrays[entry["key"]] = np.load(os.path.join(d, entry["file"]))
        if like is None:
            return step, arrays
        flat = _flatten_with_paths(like)
        flat_sh = _flatten_with_paths(shardings) if shardings is not None else None
        out_leaves = []
        for i, (key, leaf) in enumerate(flat):
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key!r} (structure changed?)")
            arr = arrays[key]
            if flat_sh is not None:
                arr = jax.device_put(arr, flat_sh[i][1])
            out_leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        return step, jax.tree_util.tree_unflatten(treedef, out_leaves)
