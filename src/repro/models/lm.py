"""Unified LM executor for all assigned architectures.

Consecutive identical layers are grouped into *segments*; each segment's
parameters are stacked on a leading ``layers`` axis and executed with
``jax.lax.scan`` (bounded compile time for 96-layer models, and the scan
body is the natural remat unit).  Weight-shared layers (zamba2's shared
attention block) hold one parameter set but per-invocation KV caches.

Decode runs against preallocated caches (attention KV / SSM state /
mLSTM matrix state), one token per step, positions passed explicitly.
Encoder-decoder (whisper) adds a non-causal encoder stack and
cross-attention caches precomputed from the encoder output.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models.specs import LayerSpec, ModelSpec, SubBlock
from repro.nn import attention as attn
from repro.nn import initializers as init
from repro.nn import moe as moe_mod
from repro.nn import mlp as mlp_mod
from repro.nn import ssm as ssm_mod
from repro.nn import xlstm as xlstm_mod
from repro.nn.norms import NORM_APPLY, NORM_INIT
from repro.nn.types import P


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str  # "stack" | "shared"
    spec: LayerSpec
    count: int
    name: str


def build_segments(layers: Tuple[LayerSpec, ...], prefix: str = "seg") -> Tuple[Segment, ...]:
    segments = []
    i = 0
    while i < len(layers):
        spec = layers[i]
        if spec.shared:
            segments.append(Segment("shared", spec, 1, f"{prefix}_{len(segments)}"))
            i += 1
            continue
        j = i
        while j < len(layers) and layers[j] == spec and not layers[j].shared:
            j += 1
        segments.append(Segment("stack", spec, j - i, f"{prefix}_{len(segments)}"))
        i = j
    return tuple(segments)


# ---------------------------------------------------------------------------
# sub-block dispatch
# ---------------------------------------------------------------------------

def _sub_init(sub: SubBlock, key, dtype):
    if sub.kind in ("attention", "cross_attention"):
        return attn.attention_init(sub.cfg, key, dtype)
    if sub.kind == "mlp":
        return mlp_mod.mlp_init(sub.cfg, key, dtype)
    if sub.kind == "moe":
        return moe_mod.moe_init(sub.cfg, key, dtype)
    if sub.kind == "mamba2":
        return ssm_mod.mamba2_init(sub.cfg, key, dtype)
    if sub.kind == "mlstm":
        return xlstm_mod.mlstm_init(sub.cfg, key, dtype)
    if sub.kind == "slstm":
        return xlstm_mod.slstm_init(sub.cfg, key, dtype)
    raise ValueError(sub.kind)


def _sub_apply(sub: SubBlock, params, x, *, positions, enc_out):
    if sub.kind == "attention":
        return attn.attention_apply(params, sub.cfg, x, positions=positions)
    if sub.kind == "cross_attention":
        return attn.attention_apply(params, sub.cfg, x, kv_x=enc_out)
    if sub.kind == "mlp":
        return mlp_mod.mlp_apply(params, sub.cfg, x)
    if sub.kind == "moe":
        return moe_mod.moe_apply(params, sub.cfg, x)
    if sub.kind == "mamba2":
        return ssm_mod.mamba2_apply(params, sub.cfg, x)
    if sub.kind == "mlstm":
        return xlstm_mod.mlstm_block_apply(params, sub.cfg, x)
    if sub.kind == "slstm":
        return xlstm_mod.slstm_block_apply(params, sub.cfg, x)
    raise ValueError(sub.kind)


def _sub_cache_init(sub: SubBlock, batch, max_seq, enc_len, dtype):
    if sub.kind == "attention":
        return attn.init_kv_cache(sub.cfg, batch, max_seq, dtype)
    if sub.kind == "cross_attention":
        return attn.init_kv_cache(sub.cfg, batch, enc_len, dtype)
    if sub.kind == "mamba2":
        return ssm_mod.init_ssm_cache(sub.cfg, batch)
    if sub.kind == "mlstm":
        return xlstm_mod.init_mlstm_cache(sub.cfg, batch)
    if sub.kind == "slstm":
        return xlstm_mod.init_slstm_cache(sub.cfg, batch)
    return {}


def _sub_prefill(sub: SubBlock, params, x, cache, pos_offset):
    """Full-sequence forward that also fills the decode cache.

    Attention runs through the same full-sequence kernel dispatch as
    :func:`attention_apply` and writes the whole prompt's K/V in one
    shot.  Recurrent kinds (mamba2/mlstm/slstm) ingest the prompt with a
    ``lax.scan`` of their decode step — one compiled program, batched
    over the prompt, and bitwise identical to the token-by-token loop it
    replaces.  Returns (y (B,S,d), new_cache).
    """
    if sub.kind == "attention":
        return attn.attention_prefill(params, sub.cfg, x, cache, pos_offset)
    if sub.kind == "cross_attention":
        return attn.cross_attention_cached(params, sub.cfg, x, cache), cache
    if sub.kind == "mlp":
        return mlp_mod.mlp_apply(params, sub.cfg, x), cache
    if sub.kind == "moe":
        return moe_mod.moe_apply(params, sub.cfg, x), cache

    def body(carry, x_t):
        y_t, new_carry = _sub_decode(sub, params, x_t[:, None], carry, 0)
        return new_carry, y_t[:, 0]

    new_cache, ys = jax.lax.scan(body, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), new_cache


def _sub_decode(sub: SubBlock, params, x, cache, pos):
    """Returns (y, new_cache)."""
    if sub.kind == "attention":
        return attn.attention_decode(params, sub.cfg, x, cache, pos)
    if sub.kind == "cross_attention":
        # cross KV is precomputed and static during decode
        q_only = attn.cross_attention_cached(params, sub.cfg, x, cache)
        return q_only, cache
    if sub.kind == "mamba2":
        return ssm_mod.mamba2_decode(params, sub.cfg, x, cache)
    if sub.kind == "mlstm":
        return xlstm_mod.mlstm_block_decode(params, sub.cfg, x, cache)
    if sub.kind == "slstm":
        return xlstm_mod.slstm_block_apply(params, sub.cfg, x, cache=cache)
    if sub.kind == "mlp":
        return mlp_mod.mlp_apply(params, sub.cfg, x), cache
    if sub.kind == "moe":
        return moe_mod.moe_apply(params, sub.cfg, x), cache
    raise ValueError(sub.kind)


# ---------------------------------------------------------------------------
# layer = sequence of pre-norm residual sub-blocks
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, spec: ModelSpec):
        self.spec = spec
        self.segments = build_segments(spec.layers)
        self.enc_segments = build_segments(spec.encoder_layers, prefix="enc")

    # -- init ---------------------------------------------------------------

    def _layer_init(self, layer: LayerSpec, key, dtype):
        params = {}
        keys = jax.random.split(key, len(layer.subs))
        for i, (sub, k) in enumerate(zip(layer.subs, keys)):
            params[f"sub_{i}"] = {
                "norm": NORM_INIT[self.spec.norm](self.spec.d_model, dtype),
                "inner": _sub_init(sub, k, dtype),
            }
        return params

    def init(self, key, dtype=jnp.float32):
        spec = self.spec
        keys = jax.random.split(key, 8 + len(self.segments) + len(self.enc_segments))
        params: Dict[str, Any] = {}
        params["embed"] = P(
            init.normal(keys[0], (spec.vocab, spec.d_model), dtype, stddev=0.02),
            ("vocab", "embed"),
        )
        if spec.positional == "learned":
            params["pos_embed"] = P(
                init.normal(keys[1], (spec.max_position, spec.d_model), dtype, stddev=0.02),
                (None, "embed"),
            )
        if not spec.tie_embeddings:
            params["head"] = P(
                init.normal(keys[2], (spec.d_model, spec.vocab), dtype, stddev=0.02),
                ("embed", "vocab"),
            )
        params["final_norm"] = NORM_INIT[spec.norm](spec.d_model, dtype)
        kidx = 3
        shared_done = False
        for seg, k in zip(self.segments, keys[kidx : kidx + len(self.segments)]):
            if seg.kind == "shared":
                if not shared_done:
                    params["shared"] = self._layer_init(seg.spec, k, dtype)
                    shared_done = True
                continue
            layer_keys = jax.random.split(k, seg.count)
            params[seg.name] = jax.vmap(
                functools.partial(self._layer_init, seg.spec, dtype=dtype)
            )(layer_keys)
        kidx += len(self.segments)
        if self.enc_segments:
            params["enc_final_norm"] = NORM_INIT[spec.norm](spec.d_model, dtype)
            for seg, k in zip(self.enc_segments, keys[kidx : kidx + len(self.enc_segments)]):
                layer_keys = jax.random.split(k, seg.count)
                params[seg.name] = jax.vmap(
                    functools.partial(self._layer_init, seg.spec, dtype=dtype)
                )(layer_keys)
        return params

    # -- forward ------------------------------------------------------------

    def _layer_apply(self, layer: LayerSpec, params, h, *, positions, enc_out):
        for i, sub in enumerate(layer.subs):
            sp = params[f"sub_{i}"]
            x = NORM_APPLY[self.spec.norm](sp["norm"], h)
            y = _sub_apply(sub, sp["inner"], x, positions=positions, enc_out=enc_out)
            h = h + y
        return h

    def _run_segments(self, segments, params, h, *, positions, enc_out):
        for seg in segments:
            if seg.kind == "shared":
                h = self._layer_apply(seg.spec, params["shared"], h, positions=positions, enc_out=enc_out)
                h = constrain(h, ("batch", None, None))
                continue

            def body(carry, layer_params, _seg=seg):
                out = self._layer_apply(
                    _seg.spec, layer_params, carry, positions=positions, enc_out=enc_out
                )
                return out, None

            if self.spec.remat:
                policy = None
                if self.spec.remat_policy == "dots":
                    policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body = jax.checkpoint(body, prevent_cse=False, policy=policy)
            if seg.count == 1:
                h, _ = body(h, jax.tree_util.tree_map(lambda x: x[0], params[seg.name]))
            elif not self.spec.scan_layers:
                for i in range(seg.count):
                    h, _ = body(h, jax.tree_util.tree_map(lambda x, _i=i: x[_i], params[seg.name]))
            else:
                h, _ = jax.lax.scan(body, h, params[seg.name])
            h = constrain(h, ("batch", None, None))
        return h

    def _embed(self, params, tokens, prefix_embeds):
        h = jnp.take(params["embed"], tokens, axis=0)
        if self.spec.embed_scale:
            h = h * (self.spec.d_model ** 0.5)
        if prefix_embeds is not None:
            npfx = prefix_embeds.shape[1]
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h[:, npfx:]], axis=1)
        return h

    def _head(self, params, h):
        h = NORM_APPLY[self.spec.norm](params["final_norm"], h)
        if self.spec.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
        if self.spec.logit_softcap:
            c = self.spec.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits

    def encode(self, params, frames):
        """Encoder stack on precomputed frame embeddings (stub frontend)."""
        h = frames
        if self.spec.positional == "learned":
            h = h + params["pos_embed"][: h.shape[1]][None].astype(h.dtype)
        positions = jnp.arange(h.shape[1])[None]
        h = self._run_segments(self.enc_segments, params, h, positions=positions, enc_out=None)
        return NORM_APPLY[self.spec.norm](params["enc_final_norm"], h)

    def hidden(self, params, tokens, *, prefix_embeds=None, enc_out=None, positions=None):
        """Full-sequence forward -> final normed hidden states (B, S, d).

        Used with :func:`repro.train.loss.chunked_cross_entropy` so the
        (B, S, vocab) logits never materialize at once.
        """
        h = self._embed(params, tokens, prefix_embeds)
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None]
        if self.spec.positional == "learned":
            h = h + params["pos_embed"][: h.shape[1]][None].astype(h.dtype)
        h = constrain(h, ("batch", None, None))
        h = self._run_segments(self.segments, params, h, positions=positions, enc_out=enc_out)
        return NORM_APPLY[self.spec.norm](params["final_norm"], h)

    def head_weight(self, params):
        """(weight, transposed): logits = h @ w or einsum('bsd,vd', h, w)."""
        if self.spec.tie_embeddings:
            return params["embed"], True
        return params["head"], False

    def apply(self, params, tokens, *, prefix_embeds=None, enc_out=None, positions=None):
        """Full-sequence forward -> logits (B, S, vocab)."""
        h = self._embed(params, tokens, prefix_embeds)
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None]
        if self.spec.positional == "learned":
            h = h + params["pos_embed"][: h.shape[1]][None].astype(h.dtype)
        h = constrain(h, ("batch", None, None))
        h = self._run_segments(self.segments, params, h, positions=positions, enc_out=enc_out)
        return self._head(params, h)

    # -- decode -------------------------------------------------------------

    def _layer_cache(self, layer: LayerSpec, params_layer, batch, max_seq, enc_len, enc_out, dtype):
        cache = {}
        for i, sub in enumerate(layer.subs):
            c = _sub_cache_init(sub, batch, max_seq, enc_len, dtype)
            if sub.kind == "cross_attention" and enc_out is not None:
                c = attn.precompute_cross_kv(params_layer[f"sub_{i}"]["inner"], sub.cfg, enc_out, dtype)
            cache[f"sub_{i}"] = c
        return cache

    def init_cache(self, params, batch, max_seq, *, enc_out=None, dtype=jnp.bfloat16):
        """Build the full decode cache pytree (segment-stacked)."""
        enc_len = enc_out.shape[1] if enc_out is not None else 0
        cache: Dict[str, Any] = {}
        shared_idx = 0
        for seg in self.segments:
            if seg.kind == "shared":
                cache[f"shared_{shared_idx}"] = self._layer_cache(
                    seg.spec, params["shared"], batch, max_seq, enc_len, enc_out, dtype
                )
                shared_idx += 1
                continue
            one = lambda i: self._layer_cache(
                seg.spec,
                jax.tree_util.tree_map(lambda x: x[i], params[seg.name]),
                batch, max_seq, enc_len, enc_out, dtype,
            )
            if any(sub.kind == "cross_attention" for sub in seg.spec.subs):
                layer_caches = [one(i) for i in range(seg.count)]
                cache[seg.name] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *layer_caches
                )
            else:
                c0 = one(0)
                cache[seg.name] = jax.tree_util.tree_map(
                    lambda x: jnp.tile(x[None], (seg.count,) + (1,) * x.ndim), c0
                )
        return cache

    # -- cache sharding metadata ---------------------------------------------

    _CACHE_AXES = {
        "attention": {"k": ("batch", "kv_seq", "kv_heads", None), "v": ("batch", "kv_seq", "kv_heads", None)},
        "cross_attention": {"k": ("batch", "kv_seq", "kv_heads", None), "v": ("batch", "kv_seq", "kv_heads", None)},
        "mamba2": {"conv": ("batch", None, "mlp"), "state": ("batch", "heads", None, None)},
        "mlstm": {"conv": ("batch", None, "mlp"), "c": ("batch", "heads", "mlp", None), "n": ("batch", "heads", "mlp"), "m": ("batch", "heads")},
        "slstm": {"conv": ("batch", None, None), "c": ("batch", "heads", "mlp"), "n": ("batch", "heads", "mlp"), "m": ("batch", "heads", "mlp"), "h": ("batch", "heads", "mlp")},
        "mlp": {},
        "moe": {},
    }

    def cache_axes(self):
        """Logical-axis tree matching :meth:`init_cache`'s structure.

        Stacked (per-segment) leaves gain a leading layers dim; the
        sharding resolver pads missing leading axes with None, so the
        same tuples serve both stacked and shared entries.
        """
        axes: Dict[str, Any] = {}
        shared_idx = 0
        for seg in self.segments:
            entry = {
                f"sub_{i}": dict(self._CACHE_AXES[sub.kind])
                for i, sub in enumerate(seg.spec.subs)
            }
            if seg.kind == "shared":
                axes[f"shared_{shared_idx}"] = entry
                shared_idx += 1
            else:
                axes[seg.name] = entry
        return axes

    def _layer_decode(self, layer: LayerSpec, params, cache, h, pos):
        new_cache = {}
        for i, sub in enumerate(layer.subs):
            sp = params[f"sub_{i}"]
            x = NORM_APPLY[self.spec.norm](sp["norm"], h)
            y, new_cache[f"sub_{i}"] = _sub_decode(sub, sp["inner"], x, cache[f"sub_{i}"], pos)
            h = h + y
        return h, new_cache

    def _layer_prefill(self, layer: LayerSpec, params, cache, h, pos_offset):
        new_cache = {}
        for i, sub in enumerate(layer.subs):
            sp = params[f"sub_{i}"]
            x = NORM_APPLY[self.spec.norm](sp["norm"], h)
            y, new_cache[f"sub_{i}"] = _sub_prefill(
                sub, sp["inner"], x, cache[f"sub_{i}"], pos_offset)
            h = h + y
        return h, new_cache

    def prefill(self, params, cache, tokens, pos_offset=0):
        """Batched prefill: the whole prompt in one full-sequence forward
        that also fills the decode caches.  tokens: (B, S) int32.

        Returns (logits (B, S, vocab), new_cache); decoding continues
        from ``pos = pos_offset + S`` with :meth:`decode`.  Replaces the
        token-by-token ``decode`` loop over the prompt (quadratic in
        prompt length, and meaningless to measure prefill latency on).
        """
        h = self._embed(params, tokens, None)
        s = tokens.shape[1]
        if self.spec.positional == "learned":
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], pos_offset, s, axis=0)
            h = h + pe[None].astype(h.dtype)
        new_cache: Dict[str, Any] = {}
        shared_idx = 0
        for seg in self.segments:
            if seg.kind == "shared":
                key = f"shared_{shared_idx}"
                h, new_cache[key] = self._layer_prefill(
                    seg.spec, params["shared"], cache[key], h, pos_offset)
                shared_idx += 1
                continue

            def body(carry, inp, _seg=seg):
                lp, lc = inp
                out, nc = self._layer_prefill(_seg.spec, lp, lc, carry, pos_offset)
                return out, nc

            if seg.count == 1:
                take0 = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
                h, nc = body(h, (take0(params[seg.name]), take0(cache[seg.name])))
                new_cache[seg.name] = jax.tree_util.tree_map(lambda x: x[None], nc)
            elif not self.spec.scan_layers:
                takei = lambda t, i: jax.tree_util.tree_map(lambda x: x[i], t)
                ncs = []
                for i in range(seg.count):
                    h, nc = body(h, (takei(params[seg.name], i), takei(cache[seg.name], i)))
                    ncs.append(nc)
                new_cache[seg.name] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
            else:
                h, new_cache[seg.name] = jax.lax.scan(
                    body, h, (params[seg.name], cache[seg.name])
                )
            h = constrain(h, ("batch", None, None))
        return self._head(params, h), new_cache

    def decode(self, params, cache, tokens, pos):
        """One-step decode.  tokens: (B, 1) int32; pos: scalar int32 or
        an int32 vector (B,) of per-sequence positions (continuous
        batching: each serving slot decodes at its own depth).

        Returns (logits (B, 1, vocab), new_cache).
        """
        h = self._embed(params, tokens, None)
        pos = jnp.asarray(pos, jnp.int32)
        if self.spec.positional == "learned":
            if pos.ndim == 1:
                pe = jnp.take(params["pos_embed"], pos, axis=0)[:, None]
            else:
                pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, axis=0)[None]
            h = h + pe.astype(h.dtype)
        new_cache: Dict[str, Any] = {}
        shared_idx = 0
        for seg in self.segments:
            if seg.kind == "shared":
                key = f"shared_{shared_idx}"
                h, new_cache[key] = self._layer_decode(seg.spec, params["shared"], cache[key], h, pos)
                shared_idx += 1
                continue

            def body(carry, inp, _seg=seg):
                lp, lc = inp
                out, nc = self._layer_decode(_seg.spec, lp, lc, carry, pos)
                return out, nc

            if seg.count == 1:
                take0 = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
                h, nc = body(h, (take0(params[seg.name]), take0(cache[seg.name])))
                new_cache[seg.name] = jax.tree_util.tree_map(lambda x: x[None], nc)
            elif not self.spec.scan_layers:
                takei = lambda t, i: jax.tree_util.tree_map(lambda x: x[i], t)
                ncs = []
                for i in range(seg.count):
                    h, nc = body(h, (takei(params[seg.name], i), takei(cache[seg.name], i)))
                    ncs.append(nc)
                new_cache[seg.name] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
            else:
                h, new_cache[seg.name] = jax.lax.scan(
                    body, h, (params[seg.name], cache[seg.name])
                )
            h = constrain(h, ("batch", None, None))
        return self._head(params, h), new_cache
