"""Model specification IR.

A model is: embedding -> [LayerSpec, ...] -> final norm -> LM head.
Each LayerSpec is a tuple of residual *sub-blocks* (pre-norm residual:
``h = h + f(norm(h))``).  A standard transformer layer is
``(attention, mlp)``; a Mamba2 layer is ``(mamba2,)``; an xLSTM layer is
``(mlstm,)`` or ``(slstm,)``; a DBRX layer is ``(attention, moe)``.

The same IR is produced both by the hand-written architecture configs
(`repro/configs/*.py`) and by the NAS ModelBuilder when the search space
targets LM backbones — this is the "unified interface" of the paper
(§IV) instantiated for pod-scale models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

from repro.nn.attention import AttentionConfig
from repro.nn.mlp import MLPConfig
from repro.nn.moe import MoEConfig
from repro.nn.ssm import Mamba2Config
from repro.nn.xlstm import MLSTMConfig, SLSTMConfig

SUBBLOCK_KINDS = (
    "attention",
    "cross_attention",
    "mlp",
    "moe",
    "mamba2",
    "mlstm",
    "slstm",
)


@dataclasses.dataclass(frozen=True)
class SubBlock:
    kind: str
    cfg: Any  # one of the nn config dataclasses (frozen => hashable)

    def __post_init__(self):
        assert self.kind in SUBBLOCK_KINDS, self.kind


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    subs: Tuple[SubBlock, ...]
    shared: bool = False  # weight-tied to the model's shared block (zamba2)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    d_model: int
    vocab: int
    layers: Tuple[LayerSpec, ...]
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    positional: str = "rope"  # "rope" | "learned" | "none"
    max_position: int = 1 << 20  # learned-positional table size cap
    # Encoder (whisper): encoder layers run non-causally on frame embeddings;
    # decoder layers gain cross-attention to the encoder output.
    encoder_layers: Tuple[LayerSpec, ...] = ()
    cross_attention_every: int = 1  # decoder layers with cross-attn (1 = all)
    frontend: Optional[str] = None  # None | "audio_stub" | "vision_stub"
    num_prefix_tokens: int = 0  # vlm: patch-embedding prefix length
    logit_softcap: Optional[float] = None
    remat: bool = True
    # remat_policy: None = save nothing (max recompute, min memory);
    # "dots" = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    # (save matmul outputs, recompute elementwise only — trades memory for
    # a ~1.5x cut in recompute FLOPs; a §Perf lever).
    remat_policy: Optional[str] = None
    # scan_layers=True: lax.scan over stacked segment params (fast compile,
    # production).  False: Python-unrolled layers — used by the dry-run cost
    # lowering because XLA's HloCostAnalysis counts while bodies once.
    scan_layers: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def is_subquadratic(self) -> bool:
        """True when decode state is O(1) in context (SSM/recurrent archs,
        possibly with sliding-window attention)."""
        for layer in self.layers:
            for sub in layer.subs:
                if sub.kind == "attention" and sub.cfg.window is None:
                    return False
                if sub.kind == "cross_attention":
                    return False
        return True


def transformer_layer(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    *,
    activation: str = "silu",
    gated: bool = True,
    qk_norm: bool = False,
    attn_bias: bool = False,
    mlp_bias: bool = False,
    window: Optional[int] = None,
    rope: bool = True,
    d_head: Optional[int] = None,
    rope_theta: float = 10000.0,
) -> LayerSpec:
    """Convenience constructor for a standard decoder layer."""
    return LayerSpec(
        subs=(
            SubBlock(
                "attention",
                AttentionConfig(
                    d_model=d_model,
                    n_heads=n_heads,
                    n_kv_heads=n_kv_heads,
                    d_head=d_head,
                    use_bias=attn_bias,
                    qk_norm=qk_norm,
                    rope=rope,
                    rope_theta=rope_theta,
                    causal=True,
                    window=window,
                ),
            ),
            SubBlock(
                "mlp",
                MLPConfig(d_model, d_ff, activation=activation, gated=gated, use_bias=mlp_bias),
            ),
        )
    )


def moe_layer(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    n_experts: int,
    top_k: int,
    *,
    qk_norm: bool = False,
    dense_residual: bool = False,
    activation: str = "silu",
    capacity_factor: float = 1.25,
    rope_theta: float = 10000.0,
) -> LayerSpec:
    return LayerSpec(
        subs=(
            SubBlock(
                "attention",
                AttentionConfig(
                    d_model=d_model,
                    n_heads=n_heads,
                    n_kv_heads=n_kv_heads,
                    qk_norm=qk_norm,
                    rope=True,
                    rope_theta=rope_theta,
                    causal=True,
                ),
            ),
            SubBlock(
                "moe",
                MoEConfig(
                    d_model=d_model,
                    d_ff=d_ff,
                    n_experts=n_experts,
                    top_k=top_k,
                    capacity_factor=capacity_factor,
                    activation=activation,
                    dense_residual=dense_residual,
                ),
            ),
        )
    )
