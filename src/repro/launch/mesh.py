"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod: (16, 16) = 256 chips over
("data", "model"); multi-pod: (2, 16, 16) = 512 chips over
("pod", "data", "model").  The dry-run spoofs 512 host devices via
XLA_FLAGS (set in dryrun.py before any jax import); on real hardware the
same code paths see actual TPU devices.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax

from repro.compat import mesh_axis_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(the dry-run launcher sets this automatically)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n], **mesh_axis_kwargs(len(shape)))


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """General mesh helper for tests / small meshes / elastic re-meshing."""
    n = math.prod(shape)
    devices = devices if devices is not None else jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices[:n], **mesh_axis_kwargs(len(shape)))


def make_host_mesh():
    """1-device mesh for smoke tests and host-backend NAS measurement."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1], **mesh_axis_kwargs(2))
