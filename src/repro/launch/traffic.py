"""Deterministic serving traffic: seeded request streams + an analytic
continuous-batching simulator.

Serving quality is a property of an architecture *under load*: p99
latency depends on the arrival process, the prompt/generation length
mix, and how the engine batches — not just on single-request kernel
time.  :class:`TrafficSpec` declares that load as part of the experiment
(validated YAML, fixed seed, bit-identical replay on every backend);
:class:`ServingSim` is the discrete-event model of the serving engine
in :mod:`repro.launch.serve` — bounded admission queue, continuous
batching up to a concurrency limit, shedding when the queue is full —
driven by modelled (roofline) step costs so the traffic-shaped
estimators in :mod:`repro.evaluation.serving` are deterministic and
never read a wall clock.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

ARRIVALS = ("poisson", "uniform", "burst")


class TrafficError(ValueError):
    pass


def _require_mapping(raw: Any, where: str) -> Dict[str, Any]:
    if not isinstance(raw, Mapping):
        raise TrafficError(f"{where}: expected a mapping, got {type(raw).__name__}")
    return dict(raw)


def _length_mix(raw: Any, where: str, default_len: int) -> Dict[int, float]:
    """``{length: weight}`` mapping; a bare int or list are shorthand."""
    if raw is None:
        return {default_len: 1.0}
    if isinstance(raw, int):
        raw = {raw: 1.0}
    if isinstance(raw, (list, tuple)):
        raw = {v: 1.0 for v in raw}
    raw = _require_mapping(raw, where)
    mix: Dict[int, float] = {}
    for k, w in raw.items():
        try:
            length = int(k)
        except (TypeError, ValueError):
            raise TrafficError(f"{where}: length {k!r} is not an integer") from None
        if length < 1:
            raise TrafficError(f"{where}: length must be >= 1, got {length}")
        weight = float(w)
        if weight <= 0:
            raise TrafficError(f"{where}: weight for {length} must be > 0, got {w}")
        mix[length] = weight
    if not mix:
        raise TrafficError(f"{where}: needs at least one length: weight entry")
    total = sum(mix.values())
    return {k: v / total for k, v in sorted(mix.items())}


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request of the seeded stream."""

    id: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    token_seed: int  # per-request seed for synthetic prompt tokens

    def prompt_tokens(self, vocab: int) -> np.ndarray:
        rng = np.random.default_rng(self.token_seed)
        return rng.integers(0, vocab, self.prompt_len).astype(np.int32)


@dataclasses.dataclass
class TrafficSpec:
    """A declared, seeded traffic mix — replays bit-identically."""

    seed: int = 0
    n_requests: int = 32
    rate_rps: float = 8.0
    arrival: str = "poisson"
    prompt_lens: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {32: 1.0})
    gen_lens: Dict[int, float] = dataclasses.field(
        default_factory=lambda: {32: 1.0})

    KEYS = ("seed", "n_requests", "rate_rps", "arrival", "prompt_lens",
            "gen_lens")
    FIELD_DOCS = {
        "seed": "seed for the request stream RNG — the same seed replays "
                "the exact same arrivals, lengths, and prompt tokens on "
                "every backend (default 0)",
        "n_requests": "number of requests in the stream (integer >= 1, "
                      "default 32)",
        "rate_rps": "mean arrival rate in requests/second (> 0, default "
                    "8.0); ignored by `arrival: burst`",
        "arrival": "`poisson` (default) — exponential interarrivals | "
                   "`uniform` — evenly spaced at `1/rate_rps` | `burst` — "
                   "all requests arrive at t=0",
        "prompt_lens": "prompt-length mix as a `{length: weight}` mapping "
                       "(weights normalize); a bare integer or a list "
                       "(equal weights) are shorthand (default `{32: 1}`)",
        "gen_lens": "generation-length mix, same shape as `prompt_lens` "
                    "(default `{32: 1}`)",
    }

    @classmethod
    def from_raw(cls, raw: Any, where: str = "traffic") -> "TrafficSpec":
        if raw is None:
            return cls()
        raw = _require_mapping(raw, where)
        unknown = set(raw) - set(cls.KEYS)
        if unknown:
            raise TrafficError(
                f"{where}: unknown key(s) {sorted(unknown)}; expected a "
                f"subset of {cls.KEYS}")
        n = int(raw.get("n_requests", 32))
        if n < 1:
            raise TrafficError(f"{where}: n_requests must be >= 1, got {n}")
        rate = float(raw.get("rate_rps", 8.0))
        if rate <= 0:
            raise TrafficError(f"{where}: rate_rps must be > 0, got {rate}")
        arrival = str(raw.get("arrival", "poisson"))
        if arrival not in ARRIVALS:
            raise TrafficError(
                f"{where}: unknown arrival {arrival!r}; expected one of "
                f"{ARRIVALS}")
        return cls(
            seed=int(raw.get("seed", 0)),
            n_requests=n,
            rate_rps=rate,
            arrival=arrival,
            prompt_lens=_length_mix(raw.get("prompt_lens"),
                                    f"{where}.prompt_lens", 32),
            gen_lens=_length_mix(raw.get("gen_lens"),
                                 f"{where}.gen_lens", 32),
        )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed,
            "n_requests": self.n_requests,
            "rate_rps": self.rate_rps,
            "arrival": self.arrival,
            "prompt_lens": {int(k): float(v) for k, v in self.prompt_lens.items()},
            "gen_lens": {int(k): float(v) for k, v in self.gen_lens.items()},
        }

    # -- stream generation --------------------------------------------------

    def requests(self) -> List[Request]:
        """The seeded request stream, sorted by arrival time.  Pure
        function of the spec: same spec -> bit-identical stream."""
        rng = np.random.default_rng(self.seed)
        n = self.n_requests
        if self.arrival == "burst":
            arrivals = np.zeros(n)
        elif self.arrival == "uniform":
            arrivals = np.arange(n) / self.rate_rps
        else:  # poisson
            arrivals = np.cumsum(rng.exponential(1.0 / self.rate_rps, n))
        p_lens = np.array(sorted(self.prompt_lens), dtype=np.int64)
        p_w = np.array([self.prompt_lens[int(k)] for k in p_lens])
        g_lens = np.array(sorted(self.gen_lens), dtype=np.int64)
        g_w = np.array([self.gen_lens[int(k)] for k in g_lens])
        prompt = rng.choice(p_lens, size=n, p=p_w)
        gen = rng.choice(g_lens, size=n, p=g_w)
        seeds = rng.integers(0, 2**31 - 1, n)
        return [
            Request(id=i, arrival_s=float(arrivals[i]),
                    prompt_len=int(prompt[i]), gen_len=int(gen[i]),
                    token_seed=int(seeds[i]))
            for i in range(n)
        ]

    @property
    def max_context(self) -> int:
        """Longest prompt+generation any request of this mix can need."""
        return max(self.prompt_lens) + max(self.gen_lens)


@dataclasses.dataclass
class ServingCosts:
    """Modelled engine step costs (seconds).  ``prefill_s_per_token`` is
    paid once per prompt token when a request joins the batch;
    ``decode_step_s`` is paid per engine iteration that advances the
    whole active batch by one token."""

    prefill_s_per_token: float
    decode_step_s: float


class ServingSim:
    """Discrete-event model of the continuous-batching serving engine.

    Mirrors :class:`repro.launch.serve.ServingEngine` decision-for-
    decision — bounded admission queue (arrivals shed when it is full),
    slots filled from the queue up to ``max_batch``, joining requests
    paying prefill before the batch resumes decoding — but advances a
    simulated clock by modelled costs, so its summary is a deterministic
    pure function of (requests, costs).
    """

    def __init__(self, max_batch: int, queue_limit: int):
        if max_batch < 1:
            raise TrafficError(f"max_batch must be >= 1, got {max_batch}")
        if queue_limit < 1:
            raise TrafficError(f"queue_limit must be >= 1, got {queue_limit}")
        self.max_batch = int(max_batch)
        self.queue_limit = int(queue_limit)

    def run(self, requests: List[Request], costs: ServingCosts) -> Dict[str, Any]:
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.id))
        queue: List[Request] = []
        active: List[List[Any]] = []  # [request, tokens_done]
        now = 0.0
        shed: List[int] = []
        latencies: List[float] = []
        served = 0
        total_tokens = 0
        kv_peak_tokens = 0
        peak_active = 0

        def admit(upto: float):
            nonlocal pending
            while pending and pending[0].arrival_s <= upto:
                r = pending.pop(0)
                if len(queue) >= self.queue_limit:
                    shed.append(r.id)
                else:
                    queue.append(r)

        while pending or queue or active:
            admit(now)
            if not queue and not active:
                # idle: jump to the next arrival
                now = max(now, pending[0].arrival_s)
                admit(now)
            # fill free slots; joiners pay prefill before decode resumes
            while queue and len(active) < self.max_batch:
                r = queue.pop(0)
                now += r.prompt_len * costs.prefill_s_per_token
                active.append([r, 0])
            peak_active = max(peak_active, len(active))
            kv_now = sum(r.prompt_len + done for r, done in active)
            kv_peak_tokens = max(kv_peak_tokens, kv_now)
            if not active:
                continue
            # one engine iteration: every active slot decodes one token
            now += costs.decode_step_s
            total_tokens += len(active)
            still = []
            for slot in active:
                slot[1] += 1
                if slot[1] >= slot[0].gen_len:
                    latencies.append(now - slot[0].arrival_s)
                    served += 1
                else:
                    still.append(slot)
            active = still

        latencies.sort()
        return {
            "served": served,
            "shed": len(shed),
            "shed_ids": shed,
            "total_tokens": total_tokens,
            "makespan_s": now,
            "throughput_tok_s": total_tokens / now if now > 0 else 0.0,
            "p50_latency_s": _quantile(latencies, 0.50),
            "p99_latency_s": _quantile(latencies, 0.99),
            "peak_concurrency": peak_active,
            "kv_peak_tokens": kv_peak_tokens,
        }


def _quantile(sorted_values: List[float], q: float) -> float:
    """Nearest-rank quantile — exact, no interpolation, deterministic."""
    if not sorted_values:
        return 0.0
    n = len(sorted_values)
    rank = max(1, int(np.ceil(q * n)))
    return float(sorted_values[min(rank, n) - 1])
