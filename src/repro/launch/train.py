"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Features exercised here (pod-scale mechanics on any backend):
  * sharded params/optimizer via the logical-axis resolver
  * donated buffers (in-place param/opt updates)
  * microbatch gradient accumulation, optional gradient compression
  * async checkpointing + retention + resume (picks up after kill -9)
  * preemption handler (SIGTERM -> final checkpoint -> clean exit)
  * straggler monitor + prefetching data pipeline
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.data.pipeline import Prefetcher, SyntheticLMData
from repro.distributed.compression import GradientCompressor
from repro.distributed.fault import PreemptionHandler, StragglerMonitor
from repro.distributed.sharding import default_rules, shapes_shardings_from_axes
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import LM
from repro.nn.types import split
from repro.train.optimizer import Optimizer, OptimizerConfig, cosine_schedule
from repro.train.step import make_train_step


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--compression", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    arch = get_arch(args.arch)
    spec = arch.smoke_spec_fn() if args.smoke else arch.spec()
    model = LM(spec)
    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    rules = default_rules(mesh)
    rep = NamedSharding(mesh, PartitionSpec())

    annotated = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    params, axes = split(annotated)
    param_sh = shapes_shardings_from_axes(params, axes, mesh, rules)
    params = jax.device_put(params, param_sh)

    optimizer = Optimizer(OptimizerConfig(
        name="adamw",
        learning_rate=cosine_schedule(args.lr, warmup=max(1, args.steps // 20), total=args.steps),
    ))
    opt_state = jax.device_put(optimizer.init(params), {"step": rep, "mu": param_sh, "nu": param_sh})

    compressor = GradientCompressor() if args.compression else None
    compress_state = compressor.init_state(params) if compressor else None
    step_fn = make_train_step(model, optimizer, microbatches=args.microbatches,
                              compressor=compressor)
    donate = (0, 1)
    jit_step = jax.jit(step_fn, donate_argnums=donate)

    data = SyntheticLMData(spec.vocab, args.seq, args.global_batch)
    ckpt = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state_like = {"params": params, "opt": opt_state}
        state_sh = {"params": param_sh, "opt": {"step": rep, "mu": param_sh, "nu": param_sh}}
        start_step, restored = ckpt.restore(like=state_like, shardings=state_sh)
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start_step}", flush=True)

    prefetch = Prefetcher(data, start_step=start_step)
    preempt = PreemptionHandler()
    straggler = StragglerMonitor()
    metrics = {}
    with mesh:
        for _ in range(start_step, args.steps):
            t0 = time.time()
            step_idx, batch = prefetch.next()
            if compressor:
                params, opt_state, metrics, compress_state = jit_step(
                    params, opt_state, batch, compress_state)
            else:
                params, opt_state, metrics = jit_step(params, opt_state, batch)
            dt = time.time() - t0
            slow = straggler.record(dt)
            if (step_idx + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                print(f"[train] step {step_idx + 1} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms{' STRAGGLER' if slow else ''})", flush=True)
            if ckpt is not None and (step_idx + 1) % args.ckpt_every == 0:
                ckpt.save_async(step_idx + 1, {"params": params, "opt": opt_state})
            if preempt.preempted:
                print("[train] preemption: flushing checkpoint", flush=True)
                if ckpt is not None:
                    ckpt.save(step_idx + 1, {"params": params, "opt": opt_state})
                break
    if ckpt is not None:
        ckpt.wait()
    prefetch.close()
    final = {"final_loss": float(metrics.get("loss", float("nan"))),
             "straggler_flags": straggler.flags}
    print(json.dumps(final))
    return 0


if __name__ == "__main__":
    sys.exit(main())
