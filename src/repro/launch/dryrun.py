import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count at first init).  Everything below is ordinary code.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, input_specs  # noqa: E402
from repro.distributed.api import sharding_context  # noqa: E402
from repro.distributed.sharding import default_rules, shapes_shardings_from_axes  # noqa: E402
from repro.hwgen.hlo_analysis import parse_collectives, total_collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import LM  # noqa: E402
from repro.nn.types import split  # noqa: E402
from repro.train.optimizer import Optimizer, OptimizerConfig  # noqa: E402
from repro.train.step import make_decode_step, make_prefill_step, make_train_step  # noqa: E402

DEFAULT_OUT = "results/dryrun"

# per-arch microbatch counts for the train_4k cell (activation memory)
TRAIN_MICROBATCHES = {
    "nemotron-4-340b": 8,
    "dbrx-132b": 4,
    "arctic-480b": 4,
    "whisper-medium": 2,
}

# Layer-pattern period for the cost extrapolation (archs whose layer list
# repeats in units > 1: zamba2 = 6 mamba + 1 shared attn; xlstm = 7 mLSTM
# + 1 sLSTM).
PATTERN_UNITS = {
    "zamba2-2.7b": 7,
    "xlstm-1.3b": 8,
}


def _cell_id(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def _mem_stats(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        }
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def _cost_stats(compiled):
    try:
        from repro.compat import cost_analysis_dict

        ca = cost_analysis_dict(compiled)
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def _slice_units(spec, arch_name: str, k: int):
    """Keep the first k layer-pattern units (cost extrapolation)."""
    unit = PATTERN_UNITS.get(arch_name, 1)
    layers = tuple(spec.layers[: unit * k])
    enc = tuple(spec.encoder_layers[:k]) if spec.encoder_layers else ()
    return dataclasses.replace(spec, layers=layers, encoder_layers=enc)


def _map_sub_cfg(layers, kinds, **fields):
    out = []
    for layer in layers:
        subs = tuple(
            dataclasses.replace(s, cfg=dataclasses.replace(s.cfg, **fields))
            if s.kind in kinds else s
            for s in layer.subs
        )
        out.append(dataclasses.replace(layer, subs=subs))
    return tuple(out)


def _map_attention_cfg(layers, **fields):
    return _map_sub_cfg(layers, ("attention",), **fields)


def _swap_attention_impl(layers, impl):
    return _map_attention_cfg(layers, impl=impl)


def _map_moe_cfg(layers, **fields):
    out = []
    for layer in layers:
        subs = tuple(
            dataclasses.replace(s, cfg=dataclasses.replace(s.cfg, **fields))
            if s.kind == "moe" else s
            for s in layer.subs
        )
        out.append(dataclasses.replace(layer, subs=subs))
    return tuple(out)


def apply_variant(spec, variant):
    """§Perf hillclimb knobs, applied on top of the baseline spec.

    Comma-separated flags: chunked_attn | remat_dots | no_remat.
    (chunked_loss is a train-step knob handled in build_cell.)
    """
    if "chunked_attn" in variant:
        spec = dataclasses.replace(
            spec,
            layers=_swap_attention_impl(spec.layers, "xla_chunked"),
            encoder_layers=_swap_attention_impl(spec.encoder_layers, "xla_chunked"),
        )
    if "remat_dots" in variant:
        spec = dataclasses.replace(spec, remat_policy="dots")
    if "no_remat" in variant:
        spec = dataclasses.replace(spec, remat=False)
    if "moe_2d" in variant:
        spec = dataclasses.replace(spec, layers=_map_moe_cfg(spec.layers, shard_ff=True))
    if "seq_shard" in variant:
        spec = dataclasses.replace(
            spec,
            layers=_map_attention_cfg(spec.layers, seq_shard=True),
            encoder_layers=_map_attention_cfg(spec.encoder_layers, seq_shard=True),
        )
    for flag in variant.split(","):
        if flag.startswith("kvc") and flag[3:].isdigit():
            kvc = int(flag[3:])
            spec = dataclasses.replace(
                spec,
                layers=_map_attention_cfg(spec.layers, kv_chunk=kvc),
                encoder_layers=_map_attention_cfg(spec.encoder_layers, kv_chunk=kvc),
            )
    return spec


def build_cell(arch_name: str, shape_name: str, multi_pod: bool, *, cost_variant: bool,
               overrides=None, n_units=None, variant=""):
    """Construct (step_fn, example_args, in_shardings, out_shardings, meta)."""
    arch = get_arch(arch_name)
    cell = SHAPES[shape_name]
    spec = arch.spec(long_context=cell.long_context)
    if variant:
        spec = apply_variant(spec, variant)
    if cost_variant:
        spec = dataclasses.replace(
            spec,
            scan_layers=False,
            # unroll inner attention kv-chunk scans — honest HloCostAnalysis
            # flops.  The mLSTM chunk scan and sLSTM time scan stay while
            # loops (unrolling 256 chunk bodies x 16 layers is a compile-
            # time explosion); their flops undercount is handled by the
            # roofline's max(HLO_FLOPs, MODEL_FLOPS) compute-term floor,
            # and their collectives are trip-count-corrected by the parser.
            layers=_map_attention_cfg(spec.layers, scan_unroll=True),
            encoder_layers=_map_attention_cfg(spec.encoder_layers, scan_unroll=True),
        )
    if n_units is not None:
        spec = _slice_units(spec, arch_name, n_units)
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    model = LM(spec)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    rep = NamedSharding(mesh, PartitionSpec())

    annotated = jax.eval_shape(
        functools.partial(model.init, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
    )
    param_sds, axes = split(annotated)
    param_sh = shapes_shardings_from_axes(param_sds, axes, mesh, rules)
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(param_sds))

    batch, batch_axes = input_specs(arch, cell, spec)
    batch_sh = shapes_shardings_from_axes(batch, batch_axes, mesh, rules)
    meta = {"n_params": n_params, "mesh_shape": tuple(mesh.devices.shape),
            "seq": cell.seq, "batch": cell.batch, "kind": cell.kind}

    if cell.kind == "train":
        microbatches = 1 if cost_variant else TRAIN_MICROBATCHES.get(arch_name, 1)
        for flag in variant.split(","):
            if flag.startswith("mb") and flag[2:].isdigit() and not cost_variant:
                microbatches = int(flag[2:])
        opt = Optimizer(OptimizerConfig(name="adamw"))
        opt_sds = jax.eval_shape(opt.init, param_sds)
        opt_sh = {"step": rep, "mu": param_sh, "nu": param_sh}
        loss_chunk = 1024 if "chunked_loss" in variant else 0
        step = make_train_step(model, opt, microbatches=microbatches,
                               loss_chunk=loss_chunk, loss_unroll=cost_variant)
        meta["microbatches"] = microbatches
        return (
            step,
            (param_sds, opt_sds, batch),
            (param_sh, opt_sh, batch_sh),
            (param_sh, opt_sh, None),
            mesh,
            meta,
        )

    if cell.kind == "prefill":
        step = make_prefill_step(model, last_only="last_logit" in variant)
        return step, (param_sds, batch), (param_sh, batch_sh), None, mesh, meta

    # decode
    enc_out = None
    if arch.batch_kind == "encdec":
        enc_out = jax.ShapeDtypeStruct((cell.batch, arch.enc_context, spec.d_model), jnp.bfloat16)
    if enc_out is not None:
        cache_sds = jax.eval_shape(
            lambda p, e: model.init_cache(p, batch=cell.batch, max_seq=cell.seq,
                                          enc_out=e, dtype=jnp.bfloat16),
            param_sds, enc_out,
        )
    else:
        cache_sds = jax.eval_shape(
            functools.partial(model.init_cache, batch=cell.batch,
                              max_seq=cell.seq, dtype=jnp.bfloat16),
            param_sds,
        )
    cache_sh = shapes_shardings_from_axes(cache_sds, model.cache_axes(), mesh, rules)
    step = make_decode_step(model)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return (
        step,
        (param_sds, cache_sds, batch["tokens"], pos_sds),
        (param_sh, cache_sh, batch_sh["tokens"], rep),
        (None, cache_sh),
        mesh,
        meta,
    )


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, *,
             with_cost: bool = True, overrides=None, variant: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "cell": _cell_id(arch_name, shape_name, mesh_name),
        "variant": variant or "baseline",
    }
    arch = get_arch(arch_name)
    cell = SHAPES[shape_name]
    ok, reason = arch.cell_supported(cell)
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    t0 = time.time()
    step, args, in_sh, out_sh, mesh, meta = build_cell(
        arch_name, shape_name, multi_pod, cost_variant=False, overrides=overrides,
        variant=variant,
    )
    record.update(meta)
    with mesh, sharding_context(mesh, default_rules(mesh)):
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)
    record["memory"] = _mem_stats(compiled)
    # collectives of the production (scanned) program, for reference
    record["collectives_scanned"] = parse_collectives(compiled.as_text())
    del compiled, lowered

    if with_cost:
        # Cost variant: layers unrolled so HloCostAnalysis sees every layer.
        # Full unroll is too slow for 96-layer archs on one host core, and
        # per-layer cost is exactly additive, so we lower at two depths
        # (k1, k2 pattern units), solve q(k) = base + k*unit, extrapolate.
        t2 = time.time()
        unit = PATTERN_UNITS.get(arch_name, 1)
        spec_full = arch.spec(long_context=cell.long_context)
        full_units = len(spec_full.layers) // unit
        k1, k2 = (2, 4) if full_units >= 4 else (1, 2)
        measures = []
        for kk in (k1, k2):
            step, args, in_sh, out_sh, mesh, _ = build_cell(
                arch_name, shape_name, multi_pod, cost_variant=True,
                overrides=overrides, n_units=kk, variant=variant,
            )
            with mesh, sharding_context(mesh, default_rules(mesh)):
                lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
                compiled = lowered.compile()
            cost = _cost_stats(compiled)
            coll = parse_collectives(compiled.as_text())
            measures.append({
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes_accessed", 0.0),
                "transcendentals": cost.get("transcendentals", 0.0),
                "collective_bytes": total_collective_bytes(coll),
                "collectives": coll,
            })
            del compiled, lowered

        def extrap(q1, q2):
            u = (q2 - q1) / (k2 - k1)
            return max(0.0, q1 - k1 * u + full_units * u)

        m1, m2 = measures
        record["cost"] = {
            k: extrap(m1[k], m2[k])
            for k in ("flops", "bytes_accessed", "transcendentals")
        }
        record["collective_bytes"] = extrap(m1["collective_bytes"], m2["collective_bytes"])
        record["collectives"] = {
            kind: {
                "count": extrap(m1["collectives"][kind]["count"], m2["collectives"][kind]["count"]),
                "bytes": extrap(m1["collectives"][kind]["bytes"], m2["collectives"][kind]["bytes"]),
            }
            for kind in m1["collectives"]
        }
        record["cost_mode"] = f"extrapolated(k=({k1},{k2}),units={full_units},unit={unit})"
        record["cost_compile_s"] = round(time.time() - t2, 2)

    record["status"] = "ok"
    record["total_s"] = round(time.time() - t0, 2)
    return record


def optimized_variant(arch_name: str, shape_name: str) -> str:
    """The beyond-paper optimized configuration per cell kind (§Perf):
    derived from the three hillclimbs and applied table-wide."""
    cell = SHAPES[shape_name]
    v = []
    if cell.kind == "train":
        v += ["chunked_loss", "remat_dots", "seq_shard"]
    elif cell.kind == "prefill":
        v += ["chunked_attn", "last_logit", "seq_shard"]
    if get_arch(arch_name).family == "moe":
        v.append("moe_2d")
    return ",".join(v)


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


def main() -> int:
    p = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every (arch x shape x mesh) cell")
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=[*SHAPES, None])
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--all", action="store_true", help="run every cell via subprocesses (resumable)")
    p.add_argument("--out", default=DEFAULT_OUT)
    p.add_argument("--no-cost", action="store_true")
    p.add_argument("--variant", default="", help="comma-separated §Perf knobs: chunked_attn,chunked_loss,remat_dots,seq_shard,moe_2d,last_logit,mbN,kvcN")
    p.add_argument("--opt", action="store_true",
                   help="with --all: use the optimized per-kind variant for every cell")
    p.add_argument("--timeout", type=int, default=3600)
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = 0
        for arch, shape, mesh in all_cells():
            if args.opt and mesh == "multi":
                continue  # optimized table is single-pod (§Roofline)
            variant = optimized_variant(arch, shape) if args.opt else args.variant
            suffix = f"__{variant.replace(',', '+')}" if variant else ""
            path = os.path.join(args.out, _cell_id(arch, shape, mesh) + suffix + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", args.out]
            if variant:
                cmd += ["--variant", variant]
            # §Roofline is single-pod only; the multi-pod pass proves the
            # "pod" axis shards (compile success + memory), so skip the
            # expensive unrolled cost lowering there.
            if args.no_cost or mesh == "multi":
                cmd.append("--no-cost")
            print(f"[dryrun] {arch} x {shape} x {mesh}", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures += 1
            except subprocess.TimeoutExpired:
                failures += 1
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "status": "timeout"}, f)
        print(f"[dryrun] complete, failures={failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    suffix = f"__{args.variant.replace(',', '+')}" if args.variant else ""
    path = os.path.join(args.out, _cell_id(args.arch, args.shape, args.mesh) + suffix + ".json")
    try:
        record = run_cell(args.arch, args.shape, args.mesh == "multi",
                          with_cost=not args.no_cost, variant=args.variant)
    except Exception:
        record = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "status": "error", "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    status = record.get("status")
    print(json.dumps({k: v for k, v in record.items() if k not in ("collectives", "collectives_scanned", "traceback")}, default=str))
    if status == "error":
        print(record["traceback"][-2000:], file=sys.stderr)
    return 0 if status in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
