"""Batched serving driver: prefill + decode loop with KV/state caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Implements continuous batched greedy decoding against preallocated
caches; the same ``decode`` step the dry-run lowers at 32k/500k contexts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.lm import LM
from repro.nn.types import split


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    arch = get_arch(args.arch)
    spec = arch.smoke_spec_fn() if args.smoke else arch.spec()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, spec.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    max_seq = args.prompt_len + args.gen

    decode = jax.jit(model.decode, donate_argnums=(1,))

    # prefill by teacher-forcing the prompt through the decode path so the
    # cache is exact (batched serving uses the full prefill kernel; this
    # driver demonstrates cache correctness end to end)
    t0 = time.time()
    cache = model.init_cache(params, args.batch, max_seq, dtype=jnp.float32)
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, t : t + 1], t)
    prefill_s = time.time() - t0

    # greedy decode
    t1 = time.time()
    tokens = [jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tokens[-1], args.prompt_len + i)
        tokens.append(jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32))
    out = jnp.concatenate(tokens, axis=1)
    jax.block_until_ready(out)
    decode_s = time.time() - t1

    result = {
        "arch": spec.name,
        "batch": args.batch,
        "generated_shape": list(out.shape),
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tok_per_s": round(args.batch * (args.gen - 1) / max(decode_s, 1e-9), 1),
        "sample": out[0, :8].tolist(),
    }
    print(json.dumps(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
