"""Serving driver: continuous-batching engine + artifact-store warm boot.

Two modes share one traffic-shaped request loop (bounded admission
queue, continuous batching up to a concurrency limit, graceful shedding
when the queue is full), driven by a deterministic seeded
:class:`~repro.launch.traffic.TrafficSpec`:

LM mode — a real language model with KV/state caches::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --max-batch 4 --queue-limit 8

  Prompts run through :meth:`LM.prefill` (the full-sequence kernel, one
  forward per prompt) and join the running batch mid-flight; decode
  advances every active slot with a per-slot position vector.

Report mode — serve the winning candidate of an exploration::

    PYTHONPATH=src python -m repro.launch.serve \
        --from-report results/experiment.report.json

  Rebuilds the best architecture from the report's recorded trial
  params, then loads its compiled executable from the content-addressed
  artifact store the exploration populated — a warm boot performs
  **zero** XLA compiles (reported as ``compiles`` in the JSON summary,
  enforceable with ``--expect-compiles 0``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# shared request loop
# ---------------------------------------------------------------------------

class RequestQueue:
    """Bounded admission queue: arrivals beyond ``limit`` are shed."""

    def __init__(self, limit: int):
        self.limit = int(limit)
        self.items: List[Any] = []
        self.shed: List[Any] = []

    def offer(self, request) -> bool:
        if len(self.items) >= self.limit:
            self.shed.append(request)
            return False
        self.items.append(request)
        return True

    def take(self):
        return self.items.pop(0) if self.items else None

    def __len__(self):
        return len(self.items)


def _admit(queue: RequestQueue, pending: List[Any], upto: float) -> None:
    while pending and pending[0].arrival_s <= upto:
        queue.offer(pending.pop(0))


# ---------------------------------------------------------------------------
# LM mode: continuous batching with per-slot cache depths
# ---------------------------------------------------------------------------

class ServingEngine:
    """Continuous-batching engine for :class:`repro.models.lm.LM`.

    One batched decode cache serves ``max_batch`` slots; joining
    requests prefill at batch 1 through the full-sequence kernel and are
    merged into their slot (every cache leaf's batch axis located via
    :meth:`LM.cache_axes`), so the running batch never stalls for a
    joiner's token-by-token warmup.  Decode advances all active slots in
    one step with a per-slot position vector.  Admission is clocked by a
    simulated tick (``tick_s`` per engine iteration), so a fixed seed
    replays the same admissions, sheds, and outputs on any host.
    """

    def __init__(self, model, params, *, max_batch: int, queue_limit: int,
                 max_context: int, tick_s: float = 0.01):
        import jax
        import jax.numpy as jnp

        self.jax, self.jnp = jax, jnp
        self.model = model
        self.params = params
        self.max_batch = int(max_batch)
        self.max_context = int(max_context)
        self.tick_s = float(tick_s)
        self.queue = RequestQueue(queue_limit)
        self.cache = model.init_cache(params, self.max_batch,
                                      self.max_context, dtype=jnp.float32)
        self._axes_flat = self._batch_axes()
        self.decode = jax.jit(model.decode)
        self._prefill_jit = jax.jit(model.prefill)
        # slot i: None, or dict(req=, pos=, token=, out=[generated tokens])
        self.slots: List[Optional[Dict[str, Any]]] = [None] * self.max_batch
        self.completed: List[Dict[str, Any]] = []
        self.iterations = 0
        self.prefills = 0

    def _batch_axes(self) -> List[int]:
        """Per-cache-leaf distance of the batch axis from the right (the
        stacked-segment leading layers axis makes left-indexing wrong)."""
        import jax

        is_axes = lambda t: isinstance(t, tuple)
        axes_leaves = jax.tree_util.tree_flatten(
            self.model.cache_axes(), is_leaf=is_axes)[0]
        return [len(t) - t.index("batch") for t in axes_leaves]

    def _merge_slot(self, single_cache, slot: int) -> None:
        """Write a batch-1 prefilled cache into slot ``slot`` of the
        batched cache (dynamic_update_slice on each leaf's batch axis)."""
        jax = self.jax
        b_leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        s_leaves = jax.tree_util.tree_flatten(single_cache)[0]
        merged = []
        for b, s, from_right in zip(b_leaves, s_leaves, self._axes_flat):
            starts = [0] * b.ndim
            starts[b.ndim - from_right] = slot
            merged.append(jax.lax.dynamic_update_slice(
                b, s.astype(b.dtype), tuple(starts)))
        self.cache = jax.tree_util.tree_unflatten(treedef, merged)

    def _join(self, req) -> None:
        """Prefill one request (full-sequence kernel) into a free slot."""
        jnp = self.jnp
        slot = self.slots.index(None)
        prompt = req.prompt_tokens(self.model.spec.vocab)[None]  # (1, S)
        single = self.model.init_cache(self.params, 1, self.max_context,
                                       dtype=jnp.float32)
        logits, single = self._prefill_jit(self.params, single,
                                           jnp.asarray(prompt))
        self._merge_slot(single, slot)
        self.prefills += 1
        first = int(jnp.argmax(logits[0, -1]))
        self.slots[slot] = {"req": req, "pos": req.prompt_len,
                            "token": first, "out": [first]}

    def _decode_step(self) -> None:
        """One engine iteration: every active slot decodes one token."""
        jnp = self.jnp
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i, 0] = s["token"]
                pos[i] = s["pos"]
        logits, self.cache = self.decode(self.params, self.cache,
                                         jnp.asarray(tokens), jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s["pos"] += 1
            s["token"] = int(nxt[i])
            s["out"].append(int(nxt[i]))
            if len(s["out"]) >= s["req"].gen_len or s["pos"] + 1 >= self.max_context:
                self.completed.append({
                    "id": s["req"].id,
                    "prompt_len": s["req"].prompt_len,
                    "tokens": s["out"],
                    "finish_iter": self.iterations,
                })
                self.slots[i] = None

    def run(self, requests: List[Any]) -> Dict[str, Any]:
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.id))
        now = 0.0
        while pending or len(self.queue) or any(s is not None for s in self.slots):
            _admit(self.queue, pending, now)
            if not len(self.queue) and all(s is None for s in self.slots):
                now = max(now, pending[0].arrival_s)
                _admit(self.queue, pending, now)
            while len(self.queue) and None in self.slots:
                self._join(self.queue.take())
            if any(s is not None for s in self.slots):
                self._decode_step()
            self.iterations += 1
            now += self.tick_s
        self.completed.sort(key=lambda r: r["id"])
        return {
            "served": len(self.completed),
            "shed": len(self.queue.shed),
            "shed_ids": [r.id for r in self.queue.shed],
            "iterations": self.iterations,
            "prefills": self.prefills,
            "tokens_generated": sum(len(r["tokens"]) for r in self.completed),
        }


def _serve_lm(args) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models.lm import LM
    from repro.nn.types import split

    arch = get_arch(args.arch)
    spec = arch.smoke_spec_fn() if args.smoke else arch.spec()
    model = LM(spec)
    params, _ = split(model.init(jax.random.PRNGKey(0), dtype=jnp.float32))

    traffic = _traffic_from_args(args)
    engine = ServingEngine(
        model, params, max_batch=args.max_batch,
        queue_limit=args.queue_limit,
        max_context=min(traffic.max_context + 1, spec.max_position),
        tick_s=args.tick_ms / 1e3)
    t0 = time.time()
    summary = engine.run(traffic.requests())
    wall = time.time() - t0
    summary.update({
        "mode": "lm", "arch": spec.name,
        "traffic": traffic.to_dict(),
        "max_batch": args.max_batch, "queue_limit": args.queue_limit,
        "wall_s": round(wall, 3),
        "tok_per_s": round(summary["tokens_generated"] / max(wall, 1e-9), 1),
        "sample": engine.completed[0]["tokens"][:8] if engine.completed else [],
    })
    return summary


# ---------------------------------------------------------------------------
# report mode: warm-boot the exploration winner from the artifact store
# ---------------------------------------------------------------------------

def rebuild_best(report: Dict[str, Any]):
    """(candidate, spec) — the report's best architecture, rebuilt from
    its recorded trial params via a fixed (pre-set params) trial."""
    from repro.core.builder import ModelBuilder
    from repro.core.space import parse_search_space
    from repro.core.translate import sample_architecture
    from repro.explorer.experiment import ExperimentSpec
    from repro.search.trial import Trial

    if not report.get("best"):
        raise SystemExit("report has no best trial to serve")
    spec = ExperimentSpec.from_dict(report["spec"])
    space = parse_search_space(dict(spec.search_space))
    trial = Trial(number=report["best"].get("number", 0), study=None)
    trial.params = dict(report["best"]["params"])
    arch = sample_architecture(space, trial)
    recorded = report["best"].get("signature")
    if recorded is not None and arch.signature() != recorded:
        raise SystemExit(
            f"rebuilt architecture signature {arch.signature()!r} does not "
            f"match the report's {recorded!r}; the search space or builder "
            f"changed since the exploration")
    builder = ModelBuilder(space.input_shape, space.output_dim)
    return builder.build(arch), spec


def _serve_report(args) -> Dict[str, Any]:
    import jax.numpy as jnp

    from repro.evaluation.serving import _ServingEstimator
    from repro.hwgen.generator import generate_call_count
    from repro.launch.traffic import ServingCosts, ServingSim

    with open(args.from_report) as f:
        report = json.load(f)
    candidate, spec = rebuild_best(report)
    serving = spec.serving
    if serving is None:
        from repro.explorer.experiment import ServingSpec

        serving = ServingSpec()
    if args.requests:
        serving.traffic.n_requests = args.requests
    if spec.cache.dir is None:
        print("warning: report's experiment had no cache dir; the boot "
              "will compile instead of warm-loading", file=sys.stderr)

    est = _ServingEstimator(target=spec.target, serving=serving,
                            cache=spec.cache.dir)
    before = generate_call_count()
    t0 = time.time()
    plan = est._schedule_plan(candidate)
    artifact, (params, _x0) = est._artifact(candidate, plan)
    boot_s = time.time() - t0
    compiles = generate_call_count() - before

    # the same deterministic admission/shedding/batching model the
    # estimators ranked this candidate by, with the *loaded* executable
    # really running once per joining batch
    requests = serving.traffic.requests()
    queue = RequestQueue(serving.queue_limit)
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.id))
    seq_len = max(1, int(candidate.input_shape[-1]))
    costs = ServingCosts(
        prefill_s_per_token=est._prefill_bound_s(candidate, plan)
        / (serving.max_batch * seq_len),
        decode_step_s=est._decode_step_s(candidate))
    now, served, batches = 0.0, 0, 0
    l, c = int(candidate.input_shape[-1]), int(candidate.input_shape[0])
    t1 = time.time()
    while pending or len(queue):
        _admit(queue, pending, now)
        if not len(queue):
            now = max(now, pending[0].arrival_s)
            _admit(queue, pending, now)
        group = []
        while len(queue) and len(group) < serving.max_batch:
            group.append(queue.take())
        if not group:
            continue
        xb = np.zeros((serving.max_batch, l, c), np.float32)
        for i, req in enumerate(group):
            rng = np.random.default_rng(req.token_seed)
            xb[i] = rng.standard_normal((l, c)).astype(np.float32)
        artifact.compiled(params, jnp.asarray(xb))
        served += len(group)
        batches += 1
        now += sum(r.prompt_len for r in group) * costs.prefill_s_per_token \
            + costs.decode_step_s
    exec_s = time.time() - t1

    sim = ServingSim(max_batch=serving.max_batch,
                     queue_limit=serving.queue_limit).run(requests, costs)
    return {
        "mode": "report",
        "experiment": report.get("experiment"),
        "signature": candidate.arch.signature(),
        "target": spec.target,
        "compiles": compiles,
        "artifact_store": est.artifacts.stats() if est.artifacts else None,
        "boot_s": round(boot_s, 3),
        "served": served,
        "shed": len(queue.shed),
        "batches": batches,
        "exec_s": round(exec_s, 3),
        "traffic": serving.traffic.to_dict(),
        "modelled": {k: sim[k] for k in
                     ("p50_latency_s", "p99_latency_s", "throughput_tok_s",
                      "peak_concurrency")},
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_mix(text: Optional[str]) -> Optional[Dict[int, float]]:
    """``"8,16"`` -> equal weights; ``"8:0.75,16:0.25"`` -> weighted."""
    if not text:
        return None
    mix: Dict[int, float] = {}
    for part in text.split(","):
        if ":" in part:
            k, w = part.split(":", 1)
            mix[int(k)] = float(w)
        else:
            mix[int(part)] = 1.0
    return mix


def _traffic_from_args(args):
    from repro.launch.traffic import TrafficSpec

    raw: Dict[str, Any] = {
        "seed": args.seed, "n_requests": args.requests or 8,
        "arrival": args.arrival, "rate_rps": args.rate_rps,
    }
    if _parse_mix(args.prompt_lens):
        raw["prompt_lens"] = _parse_mix(args.prompt_lens)
    if _parse_mix(args.gen_lens):
        raw["gen_lens"] = _parse_mix(args.gen_lens)
    return TrafficSpec.from_raw(raw)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    mode = p.add_mutually_exclusive_group()
    mode.add_argument("--arch", default=None,
                      help="serve a named LM architecture")
    mode.add_argument("--from-report", default=None,
                      help="serve an exploration report's best candidate, "
                           "warm-loading its executable from the artifact "
                           "store")
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (LM mode)")
    p.add_argument("--requests", type=int, default=0,
                   help="number of requests (0 = traffic default)")
    p.add_argument("--arrival", default="burst",
                   choices=("burst", "uniform", "poisson"))
    p.add_argument("--rate-rps", type=float, default=8.0)
    p.add_argument("--prompt-lens", default="",
                   help="prompt length mix, e.g. '8,16' or '8:0.75,16:0.25'")
    p.add_argument("--gen-lens", default="",
                   help="generation length mix, same syntax")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--queue-limit", type=int, default=8)
    p.add_argument("--tick-ms", type=float, default=10.0,
                   help="simulated admission clock per engine iteration")
    p.add_argument("--expect-compiles", type=int, default=None,
                   help="exit nonzero if the boot performed more XLA "
                        "compiles than this (report mode)")
    args = p.parse_args(argv)

    if args.from_report:
        result = _serve_report(args)
    else:
        if args.arch is None:
            args.arch = "qwen3-1.7b"
            args.smoke = True
        result = _serve_lm(args)
    print(json.dumps(result))
    if args.expect_compiles is not None and args.from_report:
        if result["compiles"] > args.expect_compiles:
            print(f"FAIL: boot performed {result['compiles']} XLA "
                  f"compile(s), expected <= {args.expect_compiles}",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
