"""Chunkwise mLSTM (xLSTM matrix memory) for TPU (Pallas).

Same TPU chunking strategy as the SSD kernel: intra-chunk gated attention
panels on the MXU, inter-chunk (C, n, m) matrix-memory state carried in
VMEM scratch across the sequential chunk axis.  Exponential gates are
stabilized with the running max ``m`` exactly as the recurrent oracle.

Grid: (batch, heads, n_chunks)   [chunks sequential]
Per-block: q/k/v (Q, P); gates (Q,); state C (P, P), n (P,), m (1,) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG_NEG = -1e6


def _mlstm_kernel(
    q_ref, k_ref, v_ref, i_ref, f_ref,
    h_ref,
    c_ref, n_ref, m_ref,  # scratch: (P,P), (P,), (1,)
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, BIG_NEG)

    p_dim = q_ref.shape[-1]
    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * (p_dim ** -0.5)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    ig = i_ref[0, :, 0].astype(jnp.float32)  # (Q,) log input gate
    fg = f_ref[0, :, 0].astype(jnp.float32)  # (Q,) log forget gate

    fcum = jnp.cumsum(fg)  # inclusive
    ftot = fcum[-1]
    m_prev = m_ref[0]
    c_prev = c_ref[...]
    n_prev = n_ref[...]

    # intra log-weights a[i,j] = fcum_i - fcum_j + ig_j (j<=i); inter b[i]
    iidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a_log = jnp.where(jidx <= iidx,
                      fcum[:, None] - fcum[None, :] + ig[None, :], -jnp.inf)
    b_log = fcum + m_prev
    m_i = jnp.maximum(jnp.max(a_log, axis=1), b_log)
    m_i = jnp.maximum(m_i, BIG_NEG)

    intra_w = jnp.exp(a_log - m_i[:, None])  # (Q, Q)
    inter_w = jnp.exp(b_log - m_i)  # (Q,)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    s_intra = qk * intra_w
    h_num = jax.lax.dot_general(s_intra, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_num += jax.lax.dot_general(q, c_prev, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32) * inter_w[:, None]
    denom = jnp.sum(s_intra, axis=1)
    denom += (q @ n_prev) * inter_w
    denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_i))
    h_ref[0, :, 0, :] = (h_num / denom[:, None]).astype(h_ref.dtype)

    # state update to chunk end
    w_log = ftot - fcum + ig  # (Q,)
    m_next = jnp.maximum(ftot + m_prev, jnp.max(w_log))
    m_next = jnp.maximum(m_next, BIG_NEG)
    kw = jnp.exp(w_log - m_next)  # (Q,)
    carry = jnp.exp(ftot + m_prev - m_next)
    c_ref[...] = carry * c_prev + jax.lax.dot_general(
        k * kw[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = carry * n_prev + jnp.sum(k * kw[:, None], axis=0)
    m_ref[0] = m_next


def mlstm_scan_blhp(q, k, v, i_log, f_log, *, chunk=128, interpret=False):
    """q/k/v: (B, L, H, P); i_log/f_log: (B, L, H).  Returns h (B, L, H, P)."""
    b, l, h, p = q.shape
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, n_chunks=nc)
    seq_spec = pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0))
    gate_spec = pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((b, l, h, p), q.dtype),
        scratch_shapes=[
            _vmem((p, p), jnp.float32),
            _vmem((p,), jnp.float32),
            _vmem((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_log, f_log)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
