"""Blockwise causal GQA flash attention for TPU (Pallas).

TPU adaptation of the GPU flash-attention pattern: instead of warp-level
softmax reductions, the kernel tiles (q_block x kv_block) score panels
through VMEM and carries the online-softmax state (m, l, acc) in VMEM
scratch across the *sequential* innermost grid dimension (TPU grids
execute the trailing axis in order, which replaces the GPU's explicit
loop over KV).  Block sizes default to 128 to match the MXU's 128x128
systolic tile and the 8x128 VREG lanes.

Supports grouped-query attention natively: the kv BlockSpec index map
folds the q-head -> kv-head mapping (no KV repetition is materialized).
Optional sliding-window masking handles the zamba2 long-context regime.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks)   [last dim sequential]
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # outputs
    acc_ref, m_ref, l_ref,  # scratch
    *,
    scale: float,
    causal: bool,
    window,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bQ, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bK, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bK, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bQ, bK)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    # fully-masked panels keep m == NEG_INF; mask p explicitly so
    # exp(NEG_INF - NEG_INF) = 1 rows contribute nothing
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *, causal=True, window=None, scale=None,
    block_q=128, block_kv=128, interpret=False,
):
    """q: (B, H, S, D); k/v: (B, KH, T, D) with H % KH == 0.

    Returns (B, H, S, D).  S must divide block_q, T block_kv (ops.py pads).
    """
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    group = h // kh
    assert s % block_q == 0 and t % block_kv == 0, (s, t, block_q, block_kv)
    scale = scale if scale is not None else d ** -0.5
    n_q = s // block_q
    n_kv = t // block_kv

    grid = (b, h, n_q, n_kv)
    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, d), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
