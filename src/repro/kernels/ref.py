"""Pure-jnp oracles for every Pallas kernel.

These delegate to the nn-substrate reference implementations so the
kernels are validated against exactly the math the models use.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.attention import grouped_attention, make_mask
from repro.nn.ssm import ssd_chunked
from repro.nn.xlstm import mlstm_recurrent


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, H, S, D); k/v: (B, KH, T, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    q_ = q.transpose(0, 2, 1, 3)  # (B, S, H, D)
    k_ = k.transpose(0, 2, 1, 3)
    v_ = v.transpose(0, 2, 1, 3)
    mask = make_mask(s, t, causal, window)
    out = grouped_attention(q_, k_, v_, mask, scale)
    return out.transpose(0, 2, 1, 3)


def ssm_scan_ref(x, dt, a, b_mat, c_mat, *, chunk=128):
    """Same shapes as ssm_scan_blhp (b/c pre-expanded to per-head)."""
    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk)


def mlstm_scan_ref(q, k, v, i_log, f_log):
    """Recurrent oracle (per-step), the strictest reference."""
    h, _ = mlstm_recurrent(q, k, v, i_log, f_log)
    return h
