"""Kernel schedules: block/tile/chunk parameters as first-class values.

The Pallas kernels used to run with hard-coded block constants — the one
layer between a candidate architecture and the chip that the search
could not see.  This module makes the mapping explicit:

  * :class:`KernelSchedule` — a frozen (hashable, jit-static) record of
    the tunable launch parameters: ``block_q``/``block_kv`` for flash
    attention, ``chunk`` for the scan kernels, plus an ``interpret``
    override for forcing the Pallas interpreter;
  * :func:`validate_schedule` — per-kernel legal-range / power-of-two
    checks whose errors name the offending field;
  * :func:`effective_schedule` — the shape-clamped values a call will
    *actually* launch with.  Requested and effective schedules differ
    whenever the sequence is shorter than a block (``block_q=128`` on a
    64-token sequence runs as 64); cache keys and artifact metadata must
    carry the effective values or two requests that clamp to the same
    launch double-compile (and two that clamp apart collide);
  * :func:`use_schedules` — a context that threads per-kernel schedules
    through *tracing*: :mod:`repro.kernels.ops` resolves the active
    schedule at trace time, so a generator can retarget every kernel in
    a model without the model's call sites knowing about schedules;
  * :func:`record_kernel_calls` — a trace-time recorder: every resolved
    kernel call notes its (requested, effective, shapes) into the sink,
    which is how artifacts learn what they were built with and how the
    autotuner discovers which kernels a candidate uses (via
    ``jax.eval_shape`` — no compile).

The named ``default`` schedule is exactly the pre-schedule constants
(every block/chunk = 128), and resolving it reproduces the old kernel
path bit-for-bit (asserted in ``tests/test_schedule.py``).

Import-light on purpose: stdlib only, so the spec layer can validate
``kernel_tuning:`` sections without touching jax.
"""
from __future__ import annotations

import contextlib
import dataclasses
from contextvars import ContextVar
from typing import Any, Dict, Iterator, Mapping, Optional, Tuple


class ScheduleError(ValueError):
    """A schedule failed validation; the message names the bad field."""


# size fields each kernel understands (everything else is illegal for it)
KERNEL_FIELDS: Dict[str, Tuple[str, ...]] = {
    "flash_attention": ("block_q", "block_kv"),
    "ssm_scan": ("chunk",),
    "mlstm_scan": ("chunk",),
}

# legal range for every size field: powers of two within [MIN, MAX].
# 8 is the f32 sublane tile; 1024 comfortably exceeds any VMEM-feasible
# block for these kernels.
MIN_SIZE = 8
MAX_SIZE = 1024

_SIZE_FIELDS = ("block_q", "block_kv", "chunk")


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """One kernel's launch parameters.  ``None`` fields fall back to the
    kernel's default; frozen so an instance can be a ``jax.jit`` static
    argument and a dict key."""

    block_q: Optional[int] = None
    block_kv: Optional[int] = None
    chunk: Optional[int] = None
    # tri-state: None = backend detection (REPRO_PALLAS_INTERPRET),
    # True/False = force
    interpret: Optional[bool] = None

    def to_dict(self) -> Dict[str, Any]:
        """Set fields only — round-trips through :meth:`from_dict` and
        stays JSON-minimal for cache records / artifact metadata."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}

    @classmethod
    def from_dict(cls, raw: Mapping[str, Any]) -> "KernelSchedule":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(raw) - known)
        if unknown:
            raise ScheduleError(
                f"unknown schedule field(s) {unknown}; known fields: "
                f"{sorted(known)}")
        return cls(**dict(raw))

    def merged_over(self, base: "KernelSchedule") -> "KernelSchedule":
        """This schedule with unset fields filled from ``base``."""
        fills = {f.name: getattr(base, f.name)
                 for f in dataclasses.fields(self)
                 if getattr(self, f.name) is None}
        return dataclasses.replace(self, **fills) if fills else self


# the named default: exactly the constants the kernels shipped with
DEFAULT_SCHEDULES: Dict[str, KernelSchedule] = {
    "flash_attention": KernelSchedule(block_q=128, block_kv=128),
    "ssm_scan": KernelSchedule(chunk=128),
    "mlstm_scan": KernelSchedule(chunk=128),
}


def default_schedule(kernel: str) -> KernelSchedule:
    """The named ``default`` schedule (the pre-schedule constants)."""
    _check_kernel(kernel)
    return DEFAULT_SCHEDULES[kernel]


def _check_kernel(kernel: str) -> None:
    if kernel not in KERNEL_FIELDS:
        raise ScheduleError(
            f"unknown kernel {kernel!r}; schedulable kernels: "
            f"{sorted(KERNEL_FIELDS)}")


def validate_schedule(kernel: str, schedule: KernelSchedule) -> KernelSchedule:
    """Raise :class:`ScheduleError` (naming the offending field) unless
    every set size field applies to ``kernel``, is a power of two, and
    lies in ``[MIN_SIZE, MAX_SIZE]``.  Returns the schedule unchanged."""
    _check_kernel(kernel)
    if not isinstance(schedule, KernelSchedule):
        raise ScheduleError(
            f"{kernel}: expected a KernelSchedule, got "
            f"{type(schedule).__name__}")
    legal = KERNEL_FIELDS[kernel]
    for field in _SIZE_FIELDS:
        value = getattr(schedule, field)
        if value is None:
            continue
        if field not in legal:
            raise ScheduleError(
                f"{kernel}: field {field!r} does not apply to this kernel "
                f"(legal fields: {list(legal)})")
        if not isinstance(value, int) or isinstance(value, bool):
            raise ScheduleError(
                f"{kernel}: field {field!r} must be an integer, got "
                f"{value!r}")
        if value < MIN_SIZE or value > MAX_SIZE:
            raise ScheduleError(
                f"{kernel}: field {field!r}={value} outside the legal "
                f"range [{MIN_SIZE}, {MAX_SIZE}]")
        if value & (value - 1):
            raise ScheduleError(
                f"{kernel}: field {field!r}={value} must be a power of two")
    return schedule


def as_schedule(kernel: str, value: Any) -> KernelSchedule:
    """Coerce a mapping / KernelSchedule to a validated schedule with
    every size field filled from the kernel default."""
    if isinstance(value, Mapping):
        value = KernelSchedule.from_dict(value)
    validate_schedule(kernel, value)
    return value.merged_over(default_schedule(kernel))


# Candidate grids swept by the autotuner, default-first so a tune budget
# of 1 degenerates to the named default and a tuned pick can never lose
# to it.  Small on purpose: interpret-mode sweeps pay real wall-clock.
CANDIDATE_SCHEDULES: Dict[str, Tuple[KernelSchedule, ...]] = {
    "flash_attention": (
        KernelSchedule(block_q=128, block_kv=128),
        KernelSchedule(block_q=64, block_kv=64),
        KernelSchedule(block_q=256, block_kv=256),
        KernelSchedule(block_q=128, block_kv=64),
        KernelSchedule(block_q=64, block_kv=128),
        KernelSchedule(block_q=256, block_kv=128),
        KernelSchedule(block_q=128, block_kv=256),
    ),
    "ssm_scan": tuple(KernelSchedule(chunk=c) for c in (128, 32, 64, 256, 512)),
    "mlstm_scan": tuple(KernelSchedule(chunk=c) for c in (128, 32, 64, 256, 512)),
}

# per-field choices exposed as trial parameters in `kernel_tuning.mode:
# search` — the sampler co-optimizes these alongside the architecture
SEARCH_CHOICES: Dict[str, Tuple[int, ...]] = {
    "block_q": (64, 128, 256),
    "block_kv": (64, 128, 256),
    "chunk": (32, 64, 128, 256),
}


# ---------------------------------------------------------------------------
# effective (shape-clamped) schedules
# ---------------------------------------------------------------------------

def _clamp_block(block: int, seq: int) -> int:
    # the flash-attention clamp: never exceed the (16-floored) sequence
    return min(block, max(16, seq))


def _clamp_chunk(chunk: int, seq: int) -> int:
    # the scan clamp: halve until the chunk divides the sequence
    ck = min(chunk, seq)
    while seq % ck:
        ck //= 2
    return max(ck, 1)


def effective_schedule(kernel: str, schedule: Optional[KernelSchedule],
                       *, seq_len: int, kv_len: Optional[int] = None
                       ) -> KernelSchedule:
    """The launch parameters a call with ``schedule`` actually uses for
    these sequence lengths — the values that must reach cache keys and
    artifact metadata (a requested ``block_q=128`` on a 64-token
    sequence runs as 64; see module docstring).  ``schedule=None`` means
    the kernel default."""
    _check_kernel(kernel)
    sched = (schedule or KernelSchedule()).merged_over(default_schedule(kernel))
    if kernel == "flash_attention":
        return dataclasses.replace(
            sched,
            block_q=_clamp_block(sched.block_q, seq_len),
            block_kv=_clamp_block(sched.block_kv,
                                  seq_len if kv_len is None else kv_len))
    return dataclasses.replace(sched, chunk=_clamp_chunk(sched.chunk, seq_len))


def schedule_signature(kernel: str, schedule: KernelSchedule) -> str:
    """Canonical short form, e.g. ``flash_attention[block_kv=64,block_q=64]``
    — stable across field ordering, for cache keys and reports."""
    fields = sorted((f, getattr(schedule, f)) for f in KERNEL_FIELDS[kernel])
    inner = ",".join(f"{name}={value}" for name, value in fields)
    return f"{kernel}[{inner}]"


# ---------------------------------------------------------------------------
# trace-time threading: active schedules + call recording
# ---------------------------------------------------------------------------

_ACTIVE: ContextVar[Optional[Dict[str, KernelSchedule]]] = ContextVar(
    "repro_active_kernel_schedules", default=None)
_SINK: ContextVar[Optional[Dict[Tuple[str, str], Dict[str, Any]]]] = ContextVar(
    "repro_kernel_call_sink", default=None)


@contextlib.contextmanager
def use_schedules(schedules: Optional[Mapping[str, Any]]) -> Iterator[None]:
    """Make per-kernel schedules active for every kernel call resolved
    inside the block (including calls reached through jit tracing, which
    runs the resolver in Python).  Values may be ``KernelSchedule``
    instances or plain field mappings; everything is validated up front.
    An active schedule overrides call-site block/chunk kwargs — that is
    the point: the generator retargets kernels the model's layers
    configured with their own constants.  ``None``/empty is a no-op."""
    if not schedules:
        yield
        return
    resolved = {k: as_schedule(k, v) for k, v in schedules.items()}
    token = _ACTIVE.set(resolved)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_schedule(kernel: str) -> Optional[KernelSchedule]:
    active = _ACTIVE.get()
    return active.get(kernel) if active else None


@contextlib.contextmanager
def record_kernel_calls(sink: Dict[Tuple[str, str], Dict[str, Any]]
                        ) -> Iterator[Dict[Tuple[str, str], Dict[str, Any]]]:
    """Collect every kernel call resolved inside the block into ``sink``,
    keyed by ``(kernel, shapes_signature)``.  Each entry records the
    requested and *effective* schedules plus the call's argument shapes
    and masking metadata — enough for an autotuner to rebuild synthetic
    inputs, and for artifacts to embed what they were built with.
    Composes with ``jax.eval_shape`` for a compile-free discovery pass."""
    token = _SINK.set(sink)
    try:
        yield sink
    finally:
        _SINK.reset(token)


def _shapes_signature(shapes: Mapping[str, Tuple[int, ...]]) -> str:
    return ",".join(f"{name}={'x'.join(str(d) for d in shape)}"
                    for name, shape in sorted(shapes.items()))


def note_kernel_call(kernel: str, requested: KernelSchedule,
                     effective: KernelSchedule,
                     shapes: Mapping[str, Tuple[int, ...]],
                     meta: Optional[Mapping[str, Any]] = None) -> None:
    """Called by :mod:`repro.kernels.ops` at resolve time (i.e. at trace
    time under jit/eval_shape).  No-op without an active recorder."""
    sink = _SINK.get()
    if sink is None:
        return
    shapes = {name: tuple(int(d) for d in shape)
              for name, shape in shapes.items()}
    sink[(kernel, _shapes_signature(shapes))] = {
        "kernel": kernel,
        "requested": requested,
        "effective": effective,
        "shapes": shapes,
        "meta": dict(meta or {}),
    }


def effective_signature(sink: Mapping[Tuple[str, str], Dict[str, Any]]) -> str:
    """One canonical string for every recorded call's *effective*
    schedule — the cache-key component that makes compiled-artifact
    entries schedule-aware without double-compiling requests that clamp
    to the same launch."""
    parts = []
    for (kernel, shapes_sig) in sorted(sink):
        eff = sink[(kernel, shapes_sig)]["effective"]
        parts.append(f"{shapes_sig}->{schedule_signature(kernel, eff)}")
    return ";".join(parts)
