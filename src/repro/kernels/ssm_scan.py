"""Mamba2 chunked state-space scan for TPU (Pallas).

TPU adaptation of the GPU SSD kernels (which rely on warp scans): the
sequence is chunked; intra-chunk interactions become two MXU matmuls
((C B^T) decay-weighted panel and its product with X), and the inter-chunk
state recurrence rides the *sequential* trailing grid dimension with the
(d_state x d_head) state carried in VMEM scratch — no cross-kernel
synchronization needed, unlike the GPU two-pass formulation.

Grid: (batch, heads, n_chunks)   [chunks sequential]
Per-block shapes (VMEM): x (Q, P), dt (Q,), B/C (Q, N), state (N, P) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,
    y_ref, state_out_ref,
    state_ref,  # scratch (N, P) f32
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0].astype(jnp.float32)  # scalar decay rate (negative)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)

    da = dt * a  # (Q,) log-decay
    cs = jnp.cumsum(da)  # inclusive
    total = cs[-1]

    # intra-chunk: att[i,j] = (C_i . B_j) exp(cs_i - cs_j) dt_j, j <= i
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    iidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logdecay = jnp.where(jidx <= iidx, cs[:, None] - cs[None, :], -jnp.inf)
    att = cb * jnp.exp(logdecay) * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: y += (C exp(cs)) @ state
    state = state_ref[...]
    y += jax.lax.dot_general(cmat * jnp.exp(cs)[:, None], state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: S <- exp(total) S + sum_j exp(total - cs_j) dt_j B_j x_j
    w = jnp.exp(total - cs) * dt  # (Q,)
    s_chunk = jax.lax.dot_general(bmat * w[:, None], x,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = jnp.exp(total) * state + s_chunk

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssm_scan_blhp(x, dt, a, b_mat, c_mat, *, chunk=128, interpret=False):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) [post-softplus]; a: (H,) negative;
    b_mat/c_mat: (B, L, H, N)  (groups pre-expanded by ops.py).
    Returns (y (B, L, H, P), final_state (B, H, N, P) f32).
    """
    b, l, h, p = x.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    grid = (b, h, nc)
    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, n, p), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[_vmem((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, state


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
