"""Jit-ready wrappers around the Pallas kernels.

Handle layout transposes, group expansion, sequence padding to block
multiples, and interpret-mode selection (Pallas TPU kernels execute via
the interpreter on non-TPU backends — how this container validates them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.envvars import read_env
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mlstm_scan import mlstm_scan_blhp
from repro.kernels.ssm_scan import ssm_scan_blhp


def _interpret() -> bool:
    # REPRO_PALLAS_INTERPRET is declared in repro.envvars (the shared
    # REPRO_* registry); unset falls back to backend detection
    return read_env("REPRO_PALLAS_INTERPRET", jax.default_backend() != "tpu")


def _pad_seq(x, block, axis):
    s = x.shape[axis]
    pad = (-s) % block
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=128, block_kv=128):
    """q: (B, S, H, D); k/v: (B, T, KH, D)  [model layout] -> (B, S, H, D)."""
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    s0, t0 = qT.shape[2], kT.shape[2]
    bq = min(block_q, max(16, s0))
    bkv = min(block_kv, max(16, t0))
    qT, _ = _pad_seq(qT, bq, 2)
    kT, _ = _pad_seq(kT, bkv, 2)
    vT, _ = _pad_seq(vT, bkv, 2)
    # padded kv columns must be masked: rely on causal/window for tail; for
    # non-causal pads, mask via window=None + explicit kv validity
    out = flash_attention_bhsd(
        qT, kT, vT, causal=causal, window=window, scale=scale,
        block_q=bq, block_kv=bkv, interpret=_interpret(),
    )
    return out[:, :, :s0].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan(x, dt, a, b_grouped, c_grouped, *, chunk=128):
    """Mamba2 SSD scan.  x: (B,L,H,P); dt: (B,L,H); a: (H,);
    b/c: (B,L,G,N) group layout (expanded here).  Returns (y, state)."""
    h = x.shape[2]
    g = b_grouped.shape[2]
    rep = h // g
    b_mat = jnp.repeat(b_grouped, rep, axis=2)
    c_mat = jnp.repeat(c_grouped, rep, axis=2)
    ck = min(chunk, x.shape[1])
    while x.shape[1] % ck:
        ck //= 2
    return ssm_scan_blhp(x, dt, a, b_mat, c_mat, chunk=max(ck, 1),
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, i_log, f_log, *, chunk=128):
    """Chunkwise mLSTM.  All (B,L,H,P) / (B,L,H).  Returns (h, None)."""
    ck = min(chunk, q.shape[1])
    while q.shape[1] % ck:
        ck //= 2
    h = mlstm_scan_blhp(q, k, v, i_log, f_log, chunk=max(ck, 1),
                        interpret=_interpret())
    return h, None
