"""Jit-ready wrappers around the Pallas kernels.

Handle layout transposes, group expansion, sequence padding to block
multiples, and interpret-mode selection (Pallas TPU kernels execute via
the interpreter on non-TPU backends — how this container validates them).

Each public op is a plain-Python *resolver* over an inner jitted impl:
schedule resolution, shape clamping, and call recording all happen
outside jit, at trace time, so an active :func:`~repro.kernels.schedule
.use_schedules` context is read fresh on every trace (a contextvar read
inside a jitted body would be baked into the first trace and silently
reused) and the *effective* — clamped — block sizes are observable by
callers that key caches on them.  Resolution precedence:

  explicit ``schedule=``  >  active ``use_schedules`` context
      >  legacy block/chunk kwargs  >  the named ``default`` schedule.

Legacy kwargs stay deliberately unvalidated: call sites derive them from
shapes (e.g. a decrement-clamped chunk) and predate the legal-range
rules.  The context outranks them so a generator can retarget kernels
that a model's layers configured with their own constants.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.envvars import read_env
from repro.kernels import schedule as ksched
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mlstm_scan import mlstm_scan_blhp
from repro.kernels.schedule import KernelSchedule
from repro.kernels.ssm_scan import ssm_scan_blhp


def _interpret() -> bool:
    # REPRO_PALLAS_INTERPRET is declared in repro.envvars (the shared
    # REPRO_* registry); unset falls back to backend detection
    return read_env("REPRO_PALLAS_INTERPRET", jax.default_backend() != "tpu")


def _pad_seq(x, block, axis):
    s = x.shape[axis]
    pad = (-s) % block
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _resolve(kernel, schedule, legacy):
    """Apply the precedence in the module docstring; returns a fully
    populated (every size field set) KernelSchedule."""
    if schedule is not None:
        return ksched.as_schedule(kernel, schedule)
    active = ksched.active_schedule(kernel)
    if active is not None:
        return active
    legacy = {k: v for k, v in legacy.items() if v is not None}
    if legacy:
        # call-site kwargs: unvalidated by design (shape-derived values)
        return KernelSchedule(**legacy).merged_over(
            ksched.default_schedule(kernel))
    return ksched.default_schedule(kernel)


def _finish(requested, effective):
    """Pin the interpret decision into the effective schedule so the
    recorded metadata says how the kernel actually ran."""
    interp = requested.interpret
    if interp is None:
        interp = _interpret()
    return dataclasses.replace(effective, interpret=bool(interp))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_kv", "interpret"))
def _flash_attention_impl(q, k, v, *, causal, window, scale,
                          block_q, block_kv, interpret):
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    s0 = qT.shape[2]
    qT, _ = _pad_seq(qT, block_q, 2)
    kT, _ = _pad_seq(kT, block_kv, 2)
    vT, _ = _pad_seq(vT, block_kv, 2)
    # padded kv columns must be masked: rely on causal/window for tail; for
    # non-causal pads, mask via window=None + explicit kv validity
    out = flash_attention_bhsd(
        qT, kT, vT, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out[:, :, :s0].transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=None, block_kv=None, schedule=None):
    """q: (B, S, H, D); k/v: (B, T, KH, D)  [model layout] -> (B, S, H, D)."""
    requested = _resolve("flash_attention", schedule,
                         {"block_q": block_q, "block_kv": block_kv})
    s0, t0 = q.shape[1], k.shape[1]
    eff = _finish(requested, ksched.effective_schedule(
        "flash_attention", requested, seq_len=s0, kv_len=t0))
    ksched.note_kernel_call(
        "flash_attention", requested, eff,
        shapes={"q": q.shape, "k": k.shape, "v": v.shape},
        meta={"causal": causal, "window": window, "scale": scale,
              "dtype": str(q.dtype)})
    return _flash_attention_impl(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=eff.block_q, block_kv=eff.block_kv, interpret=eff.interpret)


# ---------------------------------------------------------------------------
# scan kernels
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssm_scan_impl(x, dt, a, b_grouped, c_grouped, *, chunk, interpret):
    h = x.shape[2]
    g = b_grouped.shape[2]
    rep = h // g
    b_mat = jnp.repeat(b_grouped, rep, axis=2)
    c_mat = jnp.repeat(c_grouped, rep, axis=2)
    return ssm_scan_blhp(x, dt, a, b_mat, c_mat, chunk=chunk,
                         interpret=interpret)


def ssm_scan(x, dt, a, b_grouped, c_grouped, *, chunk=None, schedule=None):
    """Mamba2 SSD scan.  x: (B,L,H,P); dt: (B,L,H); a: (H,);
    b/c: (B,L,G,N) group layout (expanded here).  Returns (y, state)."""
    requested = _resolve("ssm_scan", schedule, {"chunk": chunk})
    eff = _finish(requested, ksched.effective_schedule(
        "ssm_scan", requested, seq_len=x.shape[1]))
    ksched.note_kernel_call(
        "ssm_scan", requested, eff,
        shapes={"x": x.shape, "dt": dt.shape, "a": a.shape,
                "b": b_grouped.shape, "c": c_grouped.shape},
        meta={"dtype": str(x.dtype)})
    return _ssm_scan_impl(x, dt, a, b_grouped, c_grouped,
                          chunk=eff.chunk, interpret=eff.interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _mlstm_scan_impl(q, k, v, i_log, f_log, *, chunk, interpret):
    return mlstm_scan_blhp(q, k, v, i_log, f_log, chunk=chunk,
                           interpret=interpret)


def mlstm_scan(q, k, v, i_log, f_log, *, chunk=None, schedule=None):
    """Chunkwise mLSTM.  All (B,L,H,P) / (B,L,H).  Returns (h, None)."""
    requested = _resolve("mlstm_scan", schedule, {"chunk": chunk})
    eff = _finish(requested, ksched.effective_schedule(
        "mlstm_scan", requested, seq_len=q.shape[1]))
    ksched.note_kernel_call(
        "mlstm_scan", requested, eff,
        shapes={"q": q.shape, "k": k.shape, "v": v.shape,
                "i_log": i_log.shape, "f_log": f_log.shape},
        meta={"dtype": str(q.dtype)})
    h = _mlstm_scan_impl(q, k, v, i_log, f_log,
                         chunk=eff.chunk, interpret=eff.interpret)
    return h, None
