"""Deterministic, seeded fault injection for chaos testing.

The crash-safety story of the storage, cache, and remote layers is only
trustworthy if it is *exercised*: this module threads named fault sites
through the seams the engine already owns and lets a test (or an
operator reproducing an incident) inject crashes, delays, corruption,
and dropped frames on a fixed seed — the same schedule every run, on
every backend.

Sites (see :data:`SITES`) are crossed with actions:

``raise``
    Raise :class:`InjectedFault` at the site.  The hardened caller is
    expected to degrade (cache miss, skipped persist, resubmitted
    trial) rather than propagate.
``kill``
    ``SIGKILL`` the *current process* — a real crash, no cleanup, no
    ``finally`` blocks.  Only meaningful at sites that run inside a
    worker process/daemon (``worker.trial``); in a serial study it
    would kill the study itself.
``delay``
    Sleep ``delay_s`` seconds, then continue.  Turns races (compaction
    vs. writer, heartbeat vs. result) from rare interleavings into
    deterministic ones.
``corrupt``
    Damage the payload the site is about to commit: ``str`` payloads
    are truncated at a seeded offset (a torn write), ``bytes`` payloads
    get one seeded byte flipped (bit rot / a mangled frame).  The site
    then proceeds with the damaged payload, and the *reader's*
    integrity checks (CRC32, JSON parse, torn-tail recovery) must cope.
``drop``
    The site returns the :data:`DROP` sentinel and the caller silently
    skips the operation (an unsent frame, a swallowed record).

Plans are deterministic: every rule owns a ``random.Random`` seeded
from ``(plan seed, rule index, site, action)``, so probabilistic rules
(``p=0.25``) fire on the same hits in every run.

Activation is explicit and cheap when off: :func:`fault_point` is a
single global load + ``is None`` test until :func:`install` is called
(directly, by the ``faults:`` spec section, or by the ``REPRO_FAULTS``
environment variable — which spawned worker processes inherit, so one
knob covers the whole tree).

Must stay import-light (stdlib only): the disk cache and the kernel
transport call :func:`fault_point` on their hot paths.
"""
from __future__ import annotations

import os
import signal
import threading
import time
import zlib
from random import Random
from typing import Any, Dict, List, Optional

__all__ = [
    "SITES", "ACTIONS", "DROP", "InjectedFault", "FaultRule", "FaultPlan",
    "fault_point", "install", "uninstall", "active_plan",
]

#: Every named seam a rule may target.  Adding a site means adding a
#: ``fault_point`` call at the seam *and* hardening for what the
#: injector can now do there.
SITES = (
    "disk_cache.read",   # one record line, before parse (str payload)
    "disk_cache.write",  # one record line, before append (str payload)
    "study.persist",     # one trial JSONL line, before append (str payload)
    "transport.send",    # pickled frame payload, before write (bytes)
    "transport.recv",    # pickled frame payload, after read (bytes)
    "worker.trial",      # entering a detached trial (key = trial number)
    "executor.submit",   # executor accepting a trial (key = trial number)
    "compile",           # entering XLAGenerator.generate
)

ACTIONS = ("raise", "kill", "delay", "corrupt", "drop")


class InjectedFault(Exception):
    """Raised by a ``raise`` rule.  Hardened callers treat it exactly
    like the real fault it stands in for (an ``OSError``, a lost
    worker) — never as a test artifact to special-case."""


class _DropSentinel:
    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<faults.DROP>"


#: Returned by :func:`fault_point` when a ``drop`` rule fires; the
#: caller skips the operation (doesn't send the frame / write the line).
DROP = _DropSentinel()


def _corrupt(rng: Random, payload: Any) -> Any:
    if isinstance(payload, (bytes, bytearray)):
        buf = bytearray(payload)
        if not buf:
            return bytes(buf)
        buf[rng.randrange(len(buf))] ^= rng.randrange(1, 256)
        return bytes(buf)
    if isinstance(payload, str):
        if len(payload) <= 1:
            return ""
        return payload[:rng.randrange(1, len(payload))]
    return payload


class FaultRule:
    """One (site, action) schedule entry.

    ``p``        activation probability per eligible hit (default 1.0).
    ``times``    total activation cap (default unlimited).
    ``after``    skip the first N hits (default 0).
    ``delay_s``  sleep length for ``delay`` rules (default 0.05).
    ``key``      only hits whose ``key`` stringifies to this activate —
                 e.g. ``key=3`` on ``worker.trial`` marks trial 3 as
                 the poison trial.
    """

    __slots__ = ("site", "action", "p", "times", "after", "delay_s", "key")

    def __init__(self, site: str, action: str, *, p: float = 1.0,
                 times: Optional[int] = None, after: int = 0,
                 delay_s: float = 0.05, key: Optional[str] = None):
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r} (one of {', '.join(SITES)})")
        if action not in ACTIONS:
            raise ValueError(f"unknown fault action {action!r} (one of {', '.join(ACTIONS)})")
        if not (0.0 < p <= 1.0):
            raise ValueError(f"fault probability must be in (0, 1], got {p!r}")
        if times is not None and times < 1:
            raise ValueError(f"times must be >= 1, got {times!r}")
        if after < 0:
            raise ValueError(f"after must be >= 0, got {after!r}")
        if delay_s <= 0:
            raise ValueError(f"delay_s must be > 0, got {delay_s!r}")
        self.site = site
        self.action = action
        self.p = p
        self.times = times
        self.after = after
        self.delay_s = delay_s
        self.key = None if key is None else str(key)

    _PARAMS = ("p", "times", "after", "delay_s", "key")

    @classmethod
    def from_string(cls, segment: str) -> "FaultRule":
        """``site:action`` or ``site:action@p=0.5,times=2,key=3``."""
        head, _, params = segment.partition("@")
        site, sep, action = head.partition(":")
        if not sep:
            raise ValueError(
                f"fault rule {segment!r} must look like 'site:action[@k=v,...]'")
        kwargs: Dict[str, Any] = {}
        for pair in filter(None, (p.strip() for p in params.split(","))):
            name, sep, raw = pair.partition("=")
            if not sep or name not in cls._PARAMS:
                raise ValueError(
                    f"bad fault rule param {pair!r} (one of {', '.join(cls._PARAMS)})")
            if name == "key":
                kwargs[name] = raw
            elif name in ("p", "delay_s"):
                kwargs[name] = float(raw)
            else:
                kwargs[name] = int(raw)
        return cls(site.strip(), action.strip(), **kwargs)

    def to_string(self) -> str:
        params = []
        if self.p != 1.0:
            params.append(f"p={self.p}")
        if self.times is not None:
            params.append(f"times={self.times}")
        if self.after:
            params.append(f"after={self.after}")
        if self.delay_s != 0.05:
            params.append(f"delay_s={self.delay_s}")
        if self.key is not None:
            params.append(f"key={self.key}")
        head = f"{self.site}:{self.action}"
        return head + ("@" + ",".join(params) if params else "")

    @classmethod
    def from_dict(cls, raw: Dict[str, Any]) -> "FaultRule":
        unknown = set(raw) - {"site", "action", *cls._PARAMS}
        if unknown:
            raise ValueError(f"unknown fault rule keys: {sorted(unknown)}")
        if "site" not in raw or "action" not in raw:
            raise ValueError(f"fault rule needs 'site' and 'action': {raw!r}")
        kwargs = {k: raw[k] for k in cls._PARAMS if raw.get(k) is not None}
        return cls(raw["site"], raw["action"], **kwargs)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"site": self.site, "action": self.action}
        if self.p != 1.0:
            out["p"] = self.p
        if self.times is not None:
            out["times"] = self.times
        if self.after:
            out["after"] = self.after
        if self.delay_s != 0.05:
            out["delay_s"] = self.delay_s
        if self.key is not None:
            out["key"] = self.key
        return out


class _RuleState:
    __slots__ = ("hits", "fired", "rng")

    def __init__(self, rng: Random):
        self.hits = 0
        self.fired = 0
        self.rng = rng


class FaultPlan:
    """A seeded set of :class:`FaultRule`\\ s plus per-rule counters."""

    def __init__(self, rules: List[FaultRule], *, seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._state = [
            _RuleState(Random(zlib.crc32(
                f"{self.seed}:{i}:{r.site}:{r.action}".encode())))
            for i, r in enumerate(self.rules)
        ]

    # -- construction --------------------------------------------------------

    @classmethod
    def from_string(cls, spec: str) -> "FaultPlan":
        """``"seed=7;worker.trial:kill@key=3;disk_cache.write:corrupt@p=0.25"``"""
        seed = 0
        rules: List[FaultRule] = []
        for segment in filter(None, (s.strip() for s in spec.split(";"))):
            if segment.startswith("seed="):
                seed = int(segment[len("seed="):])
            else:
                rules.append(FaultRule.from_string(segment))
        return cls(rules, seed=seed)

    @classmethod
    def from_spec(cls, raw: Dict[str, Any]) -> "FaultPlan":
        """Dict form (the ``faults:`` experiment-spec section):
        ``{"seed": 7, "rules": [{"site": ..., "action": ...}, ...]}``.
        Rules may also be given as spec strings."""
        if not isinstance(raw, dict):
            raise ValueError(f"faults spec must be a mapping, got {type(raw).__name__}")
        unknown = set(raw) - {"seed", "rules"}
        if unknown:
            raise ValueError(f"unknown faults keys: {sorted(unknown)}")
        rules_raw = raw.get("rules") or []
        if not isinstance(rules_raw, list):
            raise ValueError("faults.rules must be a list")
        rules = [
            FaultRule.from_string(r) if isinstance(r, str) else FaultRule.from_dict(r)
            for r in rules_raw
        ]
        return cls(rules, seed=raw.get("seed", 0))

    def to_string(self) -> str:
        """The ``REPRO_FAULTS`` encoding — how a plan rides the
        environment into spawned process workers and daemons."""
        parts = [f"seed={self.seed}"] if self.seed else []
        parts.extend(r.to_string() for r in self.rules)
        return ";".join(parts)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"rules": [r.to_dict() for r in self.rules]}
        if self.seed:
            out["seed"] = self.seed
        return out

    # -- introspection -------------------------------------------------------

    def counters(self) -> List[Dict[str, Any]]:
        """Per-rule hit/activation counts (for assertions and reports)."""
        with self._lock:
            return [
                {"rule": r.to_string(), "hits": s.hits, "fired": s.fired}
                for r, s in zip(self.rules, self._state)
            ]

    # -- the injection path --------------------------------------------------

    def apply(self, site: str, payload: Any, key: Any) -> Any:
        for idx, rule in enumerate(self.rules):
            if rule.site != site:
                continue
            if rule.key is not None and (key is None or str(key) != rule.key):
                continue
            with self._lock:
                state = self._state[idx]
                state.hits += 1
                if state.hits <= rule.after:
                    continue
                if rule.times is not None and state.fired >= rule.times:
                    continue
                if rule.p < 1.0 and state.rng.random() >= rule.p:
                    continue
                state.fired += 1
                rng = state.rng
            action = rule.action
            if action == "delay":
                time.sleep(rule.delay_s)
            elif action == "corrupt":
                payload = _corrupt(rng, payload)
            elif action == "drop":
                return DROP
            elif action == "raise":
                raise InjectedFault(f"injected fault at {site}"
                                    + (f" (key={key})" if key is not None else ""))
            elif action == "kill":  # pragma: no cover - kills the process
                os.kill(os.getpid(), signal.SIGKILL)
        return payload


# -- module state ------------------------------------------------------------

_PLAN: Optional[FaultPlan] = None


def fault_point(site: str, payload: Any = None, *, key: Any = None) -> Any:
    """The seam marker.  With no plan installed this is one global load
    and an ``is None`` test — the hot path pays nothing.  With a plan,
    matching rules run in order and may raise, kill, sleep, corrupt the
    payload, or return :data:`DROP`."""
    plan = _PLAN
    if plan is None:
        return payload
    return plan.apply(site, payload, key)


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide active plan (and return it)."""
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def active_plan() -> Optional[FaultPlan]:
    return _PLAN


def _install_from_env() -> None:
    # Spawned process workers and `python -m repro.worker` daemons
    # import this module fresh and inherit the parent's environment, so
    # a plan installed via REPRO_FAULTS covers the whole process tree.
    from repro.envvars import read_env

    plan = read_env("REPRO_FAULTS", None)
    if plan is not None and plan.rules:
        install(plan)


_install_from_env()
