"""Single registry of every ``REPRO_*`` environment variable.

Each knob is declared exactly once, with its parser, default, and the
documented malformed-value fallback; the readers
(:mod:`repro.hwgen.generator`, :mod:`repro.evaluation.disk_cache`,
:mod:`repro.kernels.ops`, :mod:`repro.search.remote`,
``benchmarks/bench_roofline.py``) consult this
registry through :func:`read_env`, and ``scripts/gen_docs.py`` renders
``docs/reference/env.md`` from the same entries — the prose cannot drift
from the behaviour because they share one source of truth.

Fallback contract: a malformed value never raises.  It emits a
``RuntimeWarning`` naming the variable and the value, then behaves as if
the variable were unset — a typo'd shell export must not explode at
first compile deep inside a worker thread.  Unset or blank values are
silent and use the caller's default.

Must stay import-light (stdlib only): :mod:`repro.kernels.ops` reads it
on the kernel hot path and :mod:`repro.evaluation.disk_cache` at cache
construction, neither of which may pull in the search stack.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class EnvVar:
    """One declared environment knob: parser + documentation metadata."""

    name: str
    parse: Callable[[str], Any]  # raises ValueError on malformed input
    expected: str       # what a well-formed value looks like (for the warning)
    description: str    # what the knob does (docs)
    default: str        # human-readable default (docs; the *value* is the caller's)
    malformed: str      # documented fallback behaviour (docs)
    consulted_by: str   # the reading module(s) (docs)


ENV_VARS: Dict[str, EnvVar] = {}


def register_env(var: EnvVar) -> EnvVar:
    """Publish one knob.  Re-registering a name raises — two call sites
    declaring the same variable with different parsers would make the
    generated reference ambiguous."""
    if var.name in ENV_VARS and ENV_VARS[var.name] is not var:
        raise ValueError(f"environment variable {var.name!r} already registered")
    ENV_VARS[var.name] = var
    return var


def read_env(name: str, default: Any) -> Any:
    """Read + parse a registered variable.

    Unset/blank returns ``default`` silently; a value the registered
    parser rejects warns (``RuntimeWarning`` naming the variable) and
    returns ``default``.  Reading an unregistered name raises — every
    ``REPRO_*`` lookup must go through the registry or the generated
    docs lie by omission.
    """
    try:
        var = ENV_VARS[name]
    except KeyError:
        raise KeyError(
            f"environment variable {name!r} is not registered in "
            f"repro.envvars.ENV_VARS; declare it there so docs/reference/"
            f"env.md stays complete"
        ) from None
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return var.parse(raw)
    except ValueError:
        warnings.warn(
            f"ignoring malformed {name}={raw!r} (expected {var.expected}); "
            f"falling back to the default of {default!r}",
            RuntimeWarning, stacklevel=3)
        return default


def _positive_int(raw: str) -> int:
    value = int(raw)
    if value < 1:
        raise ValueError(raw)
    return value


def _clamped_int(raw: str) -> int:
    return max(1, int(raw))


def _flag(raw: str) -> bool:
    return raw not in ("0", "false")


def _non_negative_int(raw: str) -> int:
    value = int(raw)
    if value < 0:
        raise ValueError(raw)
    return value


def _positive_float(raw: str) -> float:
    value = float(raw)
    if value <= 0:
        raise ValueError(raw)
    return value


def _faults_plan(raw: str):
    # Deferred import: repro.faults is stdlib-only, but envvars must not
    # pull it in unless the knob is actually set.
    from repro.faults import FaultPlan

    try:
        return FaultPlan.from_string(raw)
    except ValueError:
        raise
    except Exception as e:  # int()/float() garbage inside a rule param
        raise ValueError(str(e))


def _addr_list(raw: str) -> list:
    addrs = [part.strip() for part in raw.split(",") if part.strip()]
    for addr in addrs:
        host, _, port = addr.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(raw)
    if not addrs:
        raise ValueError(raw)
    return addrs


# -- the registry ------------------------------------------------------------
# Declared here, read elsewhere: generator/disk_cache/ops/bench_roofline call
# read_env() with their own computed defaults.

register_env(EnvVar(
    name="REPRO_COMPILE_CONCURRENCY",
    parse=_clamped_int,
    expected="an integer",
    description=(
        "Maximum concurrent XLA compilations per process (the admission "
        "gate around the generate/benchmark pipeline).  XLA's compiler "
        "has its own internal thread pool, so unbounded concurrent "
        "compiles oversubscribe the host; serializing them while workers "
        "overlap tracing/init/benchmarking pipelines the study instead."),
    default="`cpu_count / 2` (minimum 1)",
    malformed=("warns and uses the default; values below 1 clamp to 1 "
               "(a zero would deadlock every compile)"),
    consulted_by="`repro/hwgen/generator.py`",
))

register_env(EnvVar(
    name="REPRO_CACHE_MAX_ENTRIES",
    parse=_positive_int,
    expected="a positive integer",
    description=(
        "Record cap for the disk cache's `entries.jsonl`.  An append "
        "that pushes the file past the cap triggers an in-place "
        "rewrite under `flock`: superseded-toolchain records are "
        "dropped first, then least-recently-used records down to ~75% "
        "of the cap (headroom so steady-state appends don't rewrite "
        "every time)."),
    default="unset — the store grows without bound (append-only)",
    malformed="warns and leaves the store unbounded",
    consulted_by="`repro/evaluation/disk_cache.py`",
))

register_env(EnvVar(
    name="REPRO_CACHE_DIR",
    parse=str,
    expected="a directory path",
    description=(
        "Overrides the store directory of every disk evaluation cache "
        "opened in the process, regardless of the path the spec or "
        "constructor asked for.  Worker daemons (`python -m repro.worker "
        "--cache-dir ...`) set it so experiment specs shipped from a "
        "submitting host — whose `cache.dir` names a path that only "
        "exists over there — land in the worker's local or "
        "cluster-shared store instead."),
    default="unset — the spec/constructor path is used as-is",
    malformed="not applicable — every non-blank value is a valid path",
    consulted_by="`repro/evaluation/disk_cache.py`",
))

register_env(EnvVar(
    name="REPRO_REMOTE_WORKERS",
    parse=lambda raw: _addr_list(raw),
    expected="a comma-separated list of host:port addresses",
    description=(
        "Default worker pool for the remote executor: a comma-separated "
        "`host:port` list (e.g. `10.0.0.4:7471,10.0.0.5:7471`) consulted "
        "when neither the `executor.workers` spec key nor the "
        "constructor argument names one.  Lets `--backend remote` on the "
        "CLI work without editing the experiment YAML."),
    default="unset — the executor requires an explicit worker list",
    malformed="warns and behaves as unset",
    consulted_by="`repro/search/remote/executor.py`",
))

register_env(EnvVar(
    name="REPRO_REMOTE_TIMEOUT_S",
    parse=_positive_float,
    expected="a positive number of seconds",
    description=(
        "Heartbeat timeout for remote workers: a worker silent for "
        "longer (no heartbeat, report, ack, or result) is declared dead, "
        "its connection is closed, and its in-flight trial is resubmitted "
        "to a sibling.  Worker daemons heartbeat every "
        "`REPRO_REMOTE_HEARTBEAT_S` seconds, so the timeout should be a "
        "comfortable multiple of that.  The `heartbeat_timeout_s` "
        "executor option wins over the environment."),
    default="10.0",
    malformed="warns and uses the default",
    consulted_by="`repro/search/remote/client.py`",
))

register_env(EnvVar(
    name="REPRO_REMOTE_HEARTBEAT_S",
    parse=_positive_float,
    expected="a positive number of seconds",
    description=(
        "Interval at which a worker daemon sends heartbeat frames on "
        "each live connection (the liveness signal behind "
        "`REPRO_REMOTE_TIMEOUT_S`).  Read by the daemon, not the "
        "executor; the `--heartbeat` CLI flag wins over the "
        "environment."),
    default="2.0",
    malformed="warns and uses the default",
    consulted_by="`repro/search/remote/worker.py`",
))

register_env(EnvVar(
    name="REPRO_REMOTE_RETRIES",
    parse=lambda raw: _non_negative_int(raw),
    expected="a non-negative integer",
    description=(
        "How many times the remote executor resubmits one trial after "
        "worker failures (death, heartbeat timeout, straggler timeout) "
        "before surfacing the failure as a study error.  Resubmission is "
        "safe because detached plans are deterministic: the retried "
        "trial reproduces the original's parameters exactly.  The "
        "`retries` executor option wins over the environment."),
    default="2",
    malformed="warns and uses the default",
    consulted_by="`repro/search/remote/client.py`",
))

register_env(EnvVar(
    name="REPRO_PALLAS_INTERPRET",
    parse=_flag,
    expected="a flag (`0`/`false` disables, anything else enables)",
    description=(
        "Force Pallas kernels into interpreter mode (`0`/`false` "
        "disables it even off-TPU).  Interpret mode is how non-TPU "
        "hosts — CI, this container — validate the TPU kernels."),
    default="enabled unless running on a TPU backend",
    malformed="not applicable — every non-blank value parses as a flag",
    consulted_by="`repro/kernels/ops.py`",
))

register_env(EnvVar(
    name="REPRO_PROXY_BATCH",
    parse=_positive_int,
    expected="a positive integer",
    description=(
        "Batch size for the zero-cost proxy estimators (`synflow`, "
        "`grad_norm`) — one eager forward/backward per candidate, so "
        "this bounds tier-0 screening cost in the fidelity cascade.  "
        "Proxy scores are rankings, not costs; the default is small on "
        "purpose.  An explicit `batch` estimator param wins over the "
        "environment."),
    default="2",
    malformed="warns and uses the default",
    consulted_by="`repro/evaluation/proxies.py`",
))

register_env(EnvVar(
    name="REPRO_TUNE_BUDGET",
    parse=_positive_int,
    expected="a positive integer",
    description=(
        "Maximum schedule candidates the kernel autotuner times per "
        "(kernel, shape-bucket) sweep.  Candidate grids are ordered "
        "default-first, so a budget of 1 degenerates to the named "
        "`default` schedule with zero search.  An explicit "
        "`kernel_tuning.budget` in the experiment spec wins over the "
        "environment."),
    default="8 (the full built-in candidate grid)",
    malformed="warns and uses the default",
    consulted_by="`repro/hwgen/autotune.py`",
))

register_env(EnvVar(
    name="REPRO_FAULTS",
    parse=_faults_plan,
    expected=("a fault-plan string: `seed=N;site:action[@k=v,...];...` "
              "(see `repro/faults.py`)"),
    description=(
        "Deterministic fault-injection plan, installed at import and "
        "inherited by spawned process workers and `python -m "
        "repro.worker` daemons.  Rules name a site "
        "(`disk_cache.read/write`, `study.persist`, "
        "`transport.send/recv`, `worker.trial`, `executor.submit`, "
        "`compile`) and an action (`raise`, `kill`, `delay`, `corrupt`, "
        "`drop`), with optional `p=`, `times=`, `after=`, `delay_s=`, "
        "and `key=` params — e.g. "
        "`seed=7;worker.trial:kill@key=3,times=2;disk_cache.write:corrupt@p=0.25`.  "
        "A `faults:` section in the experiment spec wins over the "
        "environment for the run and is re-exported to it so workers "
        "see the same plan."),
    default="unset — injection disabled, the fault points are no-ops",
    malformed="warns and leaves injection disabled",
    consulted_by="`repro/faults.py`",
))

register_env(EnvVar(
    name="REPRO_ARTIFACTS",
    parse=_flag,
    expected="a flag (`0`/`false` disables, anything else enables)",
    description=(
        "Whether disk-cached explorations also persist *compiled "
        "executables* into the content-addressed artifact store "
        "(`<cache.dir>/artifacts/`), which is what lets `python -m "
        "repro.launch.serve --from-report` boot with zero XLA compiles.  "
        "`0`/`false` keeps executables memory-only (the pre-store "
        "behaviour): scalar values still persist, serving recompiles."),
    default="enabled",
    malformed="not applicable — every non-blank value parses as a flag",
    consulted_by="`repro/evaluation/artifact_store.py`",
))

register_env(EnvVar(
    name="REPRO_QUARANTINE_DEATHS",
    parse=_positive_int,
    expected="a positive integer",
    description=(
        "How many worker deaths one trial may be implicated in before "
        "the process/remote executor quarantines it: the trial is told "
        "`FAIL` with `user_attrs[\"quarantined\"]` set instead of being "
        "resubmitted, so a poison trial (one that OOM-kills or "
        "segfaults every worker it lands on) cannot burn its retries "
        "across every sibling and drain the pool.  The "
        "`quarantine_after` executor option wins over the environment."),
    default="2",
    malformed="warns and uses the default",
    consulted_by="`repro/search/executors.py`, `repro/search/remote/executor.py`",
))

register_env(EnvVar(
    name="REPRO_DRYRUN_DIR",
    parse=str,
    expected="a directory path",
    description=("Output directory for `benchmarks/bench_roofline.py` "
                 "dry-run artifacts (compiled-program cost records)."),
    default="`results/dryrun`",
    malformed="not applicable — every non-blank value is a valid path",
    consulted_by="`benchmarks/bench_roofline.py`",
))
